//! Feature-selector meta-learner (§3.2, §3.6): backward elimination scored
//! by the base model's self-evaluation (e.g. Random Forest out-of-bag),
//! exactly the composition the paper highlights — "the feature-selector
//! meta-learner can choose the optimal input features for a Random Forest
//! model using out-of-bag self-evaluation".

use crate::dataset::{ColumnData, Dataset, MISSING_BOOL, MISSING_CAT};
use crate::learner::Learner;
use crate::model::Model;

/// Backward-elimination feature selector.
///
/// Features are removed by *masking* (every value set to missing) rather
/// than dropping columns, so the final model keeps the original dataspec
/// and serves unmodified observations.
pub struct FeatureSelectorLearner {
    pub base: Box<dyn Learner>,
    /// Maximum number of elimination rounds.
    pub max_removals: usize,
}

impl FeatureSelectorLearner {
    pub fn new(base: Box<dyn Learner>) -> FeatureSelectorLearner {
        FeatureSelectorLearner { base, max_removals: 8 }
    }
}

fn mask_column(ds: &Dataset, col: usize) -> Dataset {
    let mut out = ds.clone();
    out.columns[col] = match &ds.columns[col] {
        ColumnData::Numerical(v) => ColumnData::Numerical(vec![f32::NAN; v.len()]),
        ColumnData::Categorical(v) => ColumnData::Categorical(vec![MISSING_CAT; v.len()]),
        ColumnData::Boolean(v) => ColumnData::Boolean(vec![MISSING_BOOL; v.len()]),
        ColumnData::CategoricalSet { offsets, .. } => {
            let rows = offsets.len() - 1;
            ColumnData::CategoricalSet {
                offsets: (0..=rows as u32).collect(),
                values: vec![MISSING_CAT; rows],
            }
        }
    };
    out
}

/// Self-evaluation score of a trained model — higher is better. Accuracy
/// metrics are used as-is; loss metrics are negated.
fn self_eval_score(model: &dyn Model) -> Option<f64> {
    model.self_evaluation().map(|e| {
        if e.metric.contains("loss") || e.metric.contains("rmse") {
            -e.value
        } else {
            e.value
        }
    })
}

impl Learner for FeatureSelectorLearner {
    fn name(&self) -> &'static str {
        "FEATURE_SELECTOR"
    }

    fn label(&self) -> &str {
        self.base.label()
    }

    fn train_with_valid(
        &self,
        ds: &Dataset,
        _valid: Option<&Dataset>,
    ) -> Result<Box<dyn Model>, String> {
        let label_col = ds
            .column_index(self.base.label())
            .ok_or_else(|| format!("label column \"{}\" not found.", self.base.label()))?;
        let mut current = ds.clone();
        let mut best_model = self.base.train(&current)?;
        let mut best_score = self_eval_score(best_model.as_ref()).ok_or_else(|| {
            "the feature selector requires a base learner with self-evaluation (e.g. \
             RANDOM_FOREST with out-of-bag, or GBT with a validation split)."
                .to_string()
        })?;
        let mut active: Vec<usize> =
            (0..ds.num_columns()).filter(|&c| c != label_col).collect();

        for _round in 0..self.max_removals {
            if active.len() <= 1 {
                break;
            }
            // Try removing the least-important active feature (by the
            // current model's NUM_NODES importance; absent features are the
            // cheapest candidates).
            let importances = best_model.variable_importances();
            let nodes_vi = importances.iter().find(|v| v.kind == "NUM_NODES");
            let candidate = {
                let by_importance = |c: &usize| -> f64 {
                    let name = &ds.spec.columns[*c].name;
                    nodes_vi
                        .and_then(|vi| {
                            vi.values.iter().find(|(n, _)| n == name).map(|(_, v)| *v)
                        })
                        .unwrap_or(0.0)
                };
                *active
                    .iter()
                    .min_by(|a, b| by_importance(a).partial_cmp(&by_importance(b)).unwrap())
                    .unwrap()
            };
            let masked = mask_column(&current, candidate);
            let model = self.base.train(&masked)?;
            let score = match self_eval_score(model.as_ref()) {
                Some(s) => s,
                None => break,
            };
            if score >= best_score {
                best_score = score;
                best_model = model;
                current = masked;
                active.retain(|&c| c != candidate);
            } else {
                break; // removal hurt: stop eliminating
            }
        }
        Ok(best_model)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::evaluation_free_accuracy;
    use crate::learner::random_forest::{RandomForestConfig, RandomForestLearner};

    #[test]
    fn selector_with_rf_oob() {
        let ds = synthetic::adult_like(300, 101);
        let mut cfg = RandomForestConfig::new("income");
        cfg.num_trees = 10;
        let selector =
            FeatureSelectorLearner::new(Box::new(RandomForestLearner::new(cfg)));
        let model = selector.train(&ds).unwrap();
        let acc = evaluation_free_accuracy(model.as_ref(), &ds);
        assert!(acc > 0.7, "accuracy {acc}");
    }

    #[test]
    fn masking_keeps_spec() {
        let ds = synthetic::adult_like(50, 103);
        let masked = mask_column(&ds, 0);
        assert_eq!(masked.num_columns(), ds.num_columns());
        assert!(masked.column(0).is_missing(0));
        assert!(!masked.column(1).is_missing(0));
    }

    #[test]
    fn base_without_self_eval_rejected() {
        let ds = synthetic::adult_like(100, 105);
        let mut cfg = RandomForestConfig::new("income");
        cfg.compute_oob = false;
        cfg.num_trees = 3;
        let selector =
            FeatureSelectorLearner::new(Box::new(RandomForestLearner::new(cfg)));
        let err = match selector.train(&ds) {
            Err(e) => e,
            Ok(_) => panic!(),
        };
        assert!(err.contains("self-evaluation"), "{err}");
    }
}
