//! Human-readable model reports in the `show_model` format (Appendix B.2):
//! input features, variable importances, tree statistics histograms,
//! condition-type counts and per-depth attribute usage.

use super::tree::DecisionTree;
use super::{SelfEvaluation, Task, VariableImportance};
use crate::dataset::DataSpec;
use crate::utils::bench::bar_chart;
use crate::utils::histogram::TextHistogram;
use std::collections::BTreeMap;

/// Builds the `show_model` report for tree-based models.
pub fn describe_forest(
    model_type: &str,
    task: Task,
    spec: &DataSpec,
    label_col: usize,
    trees: &[DecisionTree],
    self_eval: Option<&SelfEvaluation>,
    importances: &[VariableImportance],
) -> String {
    let mut out = format!(
        "Type: \"{}\"\nTask: {}\nLabel: \"{}\"\n\n",
        model_type,
        task.name(),
        spec.columns[label_col].name
    );

    // Input features.
    let used = super::forest::used_attributes(trees);
    out.push_str(&format!("Input Features ({}):\n", used.len()));
    for a in &used {
        out.push_str(&format!("    {}\n", spec.columns[*a].name));
    }
    out.push('\n');

    // Variable importances (bar-chart style, as in B.2).
    for vi in importances.iter().take(2) {
        out.push_str(&format!("Variable Importance: {}:\n", vi.kind));
        let items: Vec<(String, f64)> = vi
            .values
            .iter()
            .take(8)
            .enumerate()
            .map(|(i, (name, v))| (format!("{:2}. \"{}\"", i + 1, name), *v))
            .collect();
        out.push_str(&bar_chart(&items, 15));
        out.push('\n');
    }

    if let Some(e) = self_eval {
        out.push_str(&format!(
            "Self evaluation: {} = {:.6} ({} examples)\n\n",
            e.metric, e.value, e.num_examples
        ));
    }

    // Global tree statistics.
    let total_nodes: usize = trees.iter().map(|t| t.num_nodes()).sum();
    out.push_str(&format!(
        "Number of trees: {}\nTotal number of nodes: {}\n\n",
        trees.len(),
        total_nodes
    ));

    // Number of nodes by tree.
    let mut h = TextHistogram::new();
    h.extend(trees.iter().map(|t| t.num_nodes() as f64));
    out.push_str("Number of nodes by tree:\n");
    out.push_str(&h.render(8, 10));
    out.push('\n');

    // Depth by leaves.
    let mut h = TextHistogram::new();
    for t in trees {
        h.extend(t.leaf_depths().iter().map(|&d| d as f64));
    }
    out.push_str("Depth by leafs:\n");
    out.push_str(&h.render(8, 10));
    out.push('\n');

    // Number of training obs by leaf.
    let mut h = TextHistogram::new();
    for t in trees {
        h.extend(t.nodes.iter().filter(|n| n.is_leaf()).map(|n| n.num_examples));
    }
    out.push_str("Number of training obs by leaf:\n");
    out.push_str(&h.render(8, 10));
    out.push('\n');

    // Attribute usage, total and shallow.
    let mut in_nodes: BTreeMap<usize, usize> = BTreeMap::new();
    let mut in_nodes_d0: BTreeMap<usize, usize> = BTreeMap::new();
    let mut in_nodes_d1: BTreeMap<usize, usize> = BTreeMap::new();
    let mut cond_types: BTreeMap<&'static str, usize> = BTreeMap::new();
    for t in trees {
        t.visit_internal(|n, depth| {
            if let Some(c) = &n.condition {
                *cond_types.entry(c.type_name()).or_insert(0) += 1;
                for a in c.attributes() {
                    *in_nodes.entry(a).or_insert(0) += 1;
                    if depth == 0 {
                        *in_nodes_d0.entry(a).or_insert(0) += 1;
                    }
                    if depth <= 1 {
                        *in_nodes_d1.entry(a).or_insert(0) += 1;
                    }
                }
            }
        });
    }
    let fmt_usage = |title: &str, m: &BTreeMap<usize, usize>, out: &mut String| {
        out.push_str(title);
        let mut items: Vec<(usize, usize)> = m.iter().map(|(&a, &c)| (a, c)).collect();
        items.sort_by(|a, b| b.1.cmp(&a.1));
        for (a, c) in items.into_iter().take(10) {
            out.push_str(&format!(
                "    {} : {} [{}]\n",
                c,
                spec.columns[a].name,
                spec.columns[a].semantic.name()
            ));
        }
        out.push('\n');
    };
    fmt_usage("Attribute in nodes:\n", &in_nodes, &mut out);
    fmt_usage("Attribute in nodes with depth <= 0:\n", &in_nodes_d0, &mut out);
    fmt_usage("Attribute in nodes with depth <= 1:\n", &in_nodes_d1, &mut out);

    out.push_str("Condition type in nodes:\n");
    let mut types: Vec<(&str, usize)> = cond_types.into_iter().collect();
    types.sort_by(|a, b| b.1.cmp(&a.1));
    for (name, c) in types {
        out.push_str(&format!("    {c} : {name}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::dataspec::ColumnSpec;
    use crate::model::forest::variable_importances;
    use crate::model::tree::{Condition, Node};

    fn make() -> (DataSpec, Vec<DecisionTree>) {
        let spec = DataSpec {
            columns: vec![
                ColumnSpec::numerical("age"),
                ColumnSpec::categorical("y", vec!["n".into(), "y".into()]),
            ],
        };
        let tree = DecisionTree {
            nodes: vec![
                Node {
                    condition: Some(Condition::Higher { attr: 0, threshold: 30.0 }),
                    positive: 1,
                    negative: 2,
                    missing_to_positive: false,
                    value: vec![],
                    num_examples: 10.0,
                    score: 0.4,
                },
                Node::leaf(vec![0.1, 0.9], 6.0),
                Node::leaf(vec![0.8, 0.2], 4.0),
            ],
        };
        (spec, vec![tree])
    }

    #[test]
    fn report_contains_sections() {
        let (spec, trees) = make();
        let vis = variable_importances(&trees, &spec);
        let rep = describe_forest(
            "RANDOM_FOREST",
            Task::Classification,
            &spec,
            1,
            &trees,
            None,
            &vis,
        );
        for needle in [
            "Type: \"RANDOM_FOREST\"",
            "Task: CLASSIFICATION",
            "Label: \"y\"",
            "Input Features (1):",
            "Variable Importance: NUM_AS_ROOT:",
            "Number of trees: 1",
            "Total number of nodes: 3",
            "Depth by leafs:",
            "Attribute in nodes:",
            "HigherCondition",
        ] {
            assert!(rep.contains(needle), "missing: {needle}\n{rep}");
        }
    }
}
