//! Table 5: dataset inventory of the synthetic suite.
//! Run: cargo bench --bench table5_datasets

fn main() {
    println!("{}", ydf::benchmark::table5_report());
}
