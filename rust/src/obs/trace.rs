//! Chrome trace-event recording: timed spans and instant markers,
//! serialized as the Trace Event Format JSON (`chrome://tracing`,
//! Perfetto, `speedscope` all load it).
//!
//! Span sites are **near-zero-cost while tracing is disabled**: a
//! [`begin`] is one relaxed atomic load returning an empty token, the
//! matching [`end`] sees the empty token and returns before touching its
//! argument closure — no allocation, no lock, no clock read. Enabled
//! spans buffer in memory (bounded at [`MAX_EVENTS`]; overflow drops and
//! counts) and are written once, by [`write_file`], when the traced
//! command finishes — `ydf train --trace=FILE` /
//! `ydf serve --trace=FILE`.
//!
//! Event vocabulary (see `docs/observability.md`):
//!
//! * `request` / `decode` / `wait` — the serving request lifecycle, per
//!   connection worker (enqueue → flush → score → reply).
//! * `flush` — one coalesced batcher flush, with `engine`, `rows`,
//!   `blocks` and `us` args: the per-flush engine timing record the
//!   adaptive-engine-routing roadmap item consumes.
//! * `train_iteration` / `train_tree` / `prune` — learner progress.

use crate::utils::json::Json;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Buffered-event cap: ~a few hundred MB of worst-case JSON, far above
/// any realistic trace session. Beyond it events are dropped (and the
/// drop count recorded in the written file) rather than growing without
/// bound inside a long-lived server.
pub const MAX_EVENTS: usize = 1 << 20;

/// One span/instant argument value.
pub enum ArgValue {
    U64(u64),
    F64(f64),
    Str(String),
}

struct Event {
    name: &'static str,
    /// Trace-event phase: `b'X'` = complete span, `b'i'` = instant.
    ph: u8,
    /// µs since the trace epoch.
    ts_us: f64,
    dur_us: f64,
    tid: u64,
    args: Vec<(&'static str, ArgValue)>,
}

#[derive(Default)]
struct Buffer {
    events: Vec<Event>,
    dropped: u64,
}

static ENABLED: AtomicBool = AtomicBool::new(false);

fn buffer() -> &'static Mutex<Buffer> {
    static BUF: OnceLock<Mutex<Buffer>> = OnceLock::new();
    BUF.get_or_init(|| Mutex::new(Buffer::default()))
}

/// The common time origin every `ts` is relative to (Chrome only needs
/// timestamps to be mutually consistent, not absolute).
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Stable small ids for trace `tid` fields (thread names are not
/// portable and `ThreadId` has no stable numeric form).
fn tid() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static TID: u64 = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    TID.with(|t| *t)
}

/// Whether spans are being recorded — one relaxed load.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Starts recording. Clears any previously buffered events so a new
/// trace session starts clean.
pub fn enable() {
    epoch();
    let mut buf = lock();
    buf.events.clear();
    buf.dropped = 0;
    drop(buf);
    ENABLED.store(true, Ordering::Relaxed);
}

/// Stops recording. Buffered events stay until drained.
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

fn lock() -> std::sync::MutexGuard<'static, Buffer> {
    match buffer().lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// A span start token. Empty when tracing was disabled at [`begin`] —
/// the matching [`end`] is then a no-op.
pub struct SpanStart(Option<Instant>);

/// Opens a span. When tracing is disabled this is one relaxed atomic
/// load and returns an empty token — no clock read, no allocation.
#[inline]
pub fn begin() -> SpanStart {
    if ENABLED.load(Ordering::Relaxed) {
        SpanStart(Some(Instant::now()))
    } else {
        SpanStart(None)
    }
}

/// Closes a span opened by [`begin`]. `args` is only invoked when the
/// span is live, so argument construction (string clones included) costs
/// nothing while tracing is disabled.
pub fn end<F>(start: SpanStart, name: &'static str, args: F)
where
    F: FnOnce() -> Vec<(&'static str, ArgValue)>,
{
    let Some(t0) = start.0 else { return };
    let dur_us = t0.elapsed().as_secs_f64() * 1e6;
    let ts_us = t0.saturating_duration_since(epoch()).as_secs_f64() * 1e6;
    push(Event { name, ph: b'X', ts_us, dur_us, tid: tid(), args: args() });
}

/// Records an instant marker (a point event, no duration).
pub fn instant<F>(name: &'static str, args: F)
where
    F: FnOnce() -> Vec<(&'static str, ArgValue)>,
{
    if !ENABLED.load(Ordering::Relaxed) {
        return;
    }
    let ts_us = epoch().elapsed().as_secs_f64() * 1e6;
    push(Event { name, ph: b'i', ts_us, dur_us: 0.0, tid: tid(), args: args() });
}

fn push(event: Event) {
    let mut buf = lock();
    if buf.events.len() >= MAX_EVENTS {
        buf.dropped += 1;
        return;
    }
    buf.events.push(event);
}

/// Drains every buffered event into a Chrome-trace JSON object:
/// `{"traceEvents": […], "displayTimeUnit": "ms", "droppedEvents": N}`.
/// Does not change the enabled state.
pub fn take_json() -> Json {
    let mut buf = lock();
    let events = std::mem::take(&mut buf.events);
    let dropped = std::mem::replace(&mut buf.dropped, 0);
    drop(buf);
    let trace_events = events
        .into_iter()
        .map(|e| {
            let mut j = Json::obj();
            j.set("name", Json::Str(e.name.to_string()))
                .set("ph", Json::Str((e.ph as char).to_string()))
                .set("ts", Json::Num(e.ts_us))
                .set("pid", Json::Num(1.0))
                .set("tid", Json::Num(e.tid as f64));
            if e.ph == b'X' {
                j.set("dur", Json::Num(e.dur_us));
            } else {
                // Instant scope: thread-local marker.
                j.set("s", Json::Str("t".to_string()));
            }
            if !e.args.is_empty() {
                let mut args = Json::obj();
                for (k, v) in e.args {
                    let jv = match v {
                        ArgValue::U64(x) => Json::Num(x as f64),
                        ArgValue::F64(x) => Json::Num(x),
                        ArgValue::Str(s) => Json::Str(s),
                    };
                    args.set(k, jv);
                }
                j.set("args", args);
            }
            j
        })
        .collect();
    let mut out = Json::obj();
    out.set("traceEvents", Json::Arr(trace_events))
        .set("displayTimeUnit", Json::Str("ms".to_string()))
        .set("droppedEvents", Json::Num(dropped as f64));
    out
}

/// Stops recording, drains the buffer and writes the Chrome-trace JSON
/// to `path`. Returns the number of events written.
pub fn write_file(path: &Path) -> Result<usize, String> {
    disable();
    let json = take_json();
    let count = json
        .get("traceEvents")
        .and_then(|t| t.as_arr())
        .map(|a| a.len())
        .unwrap_or(0);
    std::fs::write(path, json.to_string())
        .map_err(|e| format!("cannot write trace file {}: {e}", path.display()))?;
    Ok(count)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_spans_record_nothing() {
        disable();
        let t = begin();
        end(t, "ydf_test_trace_disabled", || {
            panic!("args closure must not run while tracing is disabled")
        });
        instant("ydf_test_trace_disabled", || {
            panic!("args closure must not run while tracing is disabled")
        });
        let events = take_json();
        let names: Vec<&str> = events
            .req_arr("traceEvents")
            .unwrap()
            .iter()
            .filter_map(|e| e.get("name").and_then(|n| n.as_str()))
            .collect();
        assert!(!names.contains(&"ydf_test_trace_disabled"));
    }

    #[test]
    fn spans_round_trip_through_json() {
        enable();
        let t = begin();
        std::thread::sleep(std::time::Duration::from_millis(1));
        end(t, "ydf_test_trace_span", || {
            vec![
                ("engine", ArgValue::Str("TestEngine".to_string())),
                ("rows", ArgValue::U64(128)),
                ("us", ArgValue::F64(12.5)),
            ]
        });
        instant("ydf_test_trace_mark", || vec![("iter", ArgValue::U64(3))]);
        let path = std::env::temp_dir()
            .join(format!("ydf_trace_test_{}.json", std::process::id()));
        write_file(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&text).expect("trace file is valid JSON");
        let _ = std::fs::remove_file(&path);
        // Re-serialize → re-parse: the round trip is lossless.
        assert_eq!(Json::parse(&parsed.to_string()).unwrap(), parsed);
        let events = parsed.req_arr("traceEvents").unwrap();
        // Other concurrently running tests may have contributed events
        // while tracing was enabled; assert on ours only.
        let span = events
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("ydf_test_trace_span"))
            .expect("recorded span present");
        assert_eq!(span.req_str("ph").unwrap(), "X");
        assert!(span.req_f64("dur").unwrap() >= 1_000.0, "slept ≥ 1 ms");
        assert!(span.req_f64("ts").unwrap() >= 0.0);
        let args = span.req("args").unwrap();
        assert_eq!(args.req_str("engine").unwrap(), "TestEngine");
        assert_eq!(args.req_f64("rows").unwrap(), 128.0);
        let mark = events
            .iter()
            .find(|e| e.get("name").and_then(|n| n.as_str()) == Some("ydf_test_trace_mark"))
            .expect("recorded instant present");
        assert_eq!(mark.req_str("ph").unwrap(), "i");
    }
}
