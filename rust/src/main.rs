//! `ydf` CLI — the command-line API of §4.1: `infer_dataspec`,
//! `show_dataspec`, `train`, `show_model`, `evaluate`, `predict`,
//! `benchmark_inference`, plus `synth` (dataset generation),
//! `benchmark_suite` (the §5 experiment harness), `serve` (the
//! micro-batching TCP serving runtime, `docs/serving.md`) and `route`
//! (the fleet routing tier: one endpoint over N `serve` backends with
//! health-checked failover).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use ydf::dataset::csv::{read_csv_file, write_csv_string};
use ydf::dataset::dataspec::{DataSpec, InferenceOptions};
use ydf::dataset::synthetic;
use ydf::learner::create_learner;
use ydf::model::io::{load_model, save_model};
use ydf::utils::json::Json;

fn usage() -> ! {
    eprintln!(
        "Yggdrasil Decision Forests (reproduction) — command line interface

USAGE: ydf <command> [--flag=value ...]

COMMANDS
  infer_dataspec   --dataset=csv:FILE --output=SPEC.json
  show_dataspec    --dataspec=SPEC.json [--dataset=csv:FILE]
  train            --dataset=csv:FILE --label=NAME --learner=NAME
                   [--param:KEY=VALUE ...] [--threads=N] [--trace=FILE]
                   --output=MODEL.json
                   (--threads: training threads — RF trains trees in
                    parallel, GBT/CART score candidate features in
                    parallel, LINEAR ignores it; bit-identical to
                    --threads=1. Defaults to YDF_TRAIN_THREADS, else 1.
                    --trace: write per-tree/per-iteration training spans
                    as Chrome trace-event JSON, loadable in
                    chrome://tracing or Perfetto. YDF_LOG=info prints
                    per-iteration training progress; docs/observability.md)
  compile          --model=MODEL.json --output=MODEL.bin
                   (lowers a trained RF/GBT to the compiled-forest
                    artifact: a versioned, checksummed flat layout that
                    mmap-loads at serve time. Every command below accepts
                    the .bin wherever it accepts MODEL.json)
  show_model       --model=MODEL.json|MODEL.bin
  evaluate         --dataset=csv:FILE --model=MODEL.json|MODEL.bin
  predict          --dataset=csv:FILE --model=MODEL.json|MODEL.bin --output=csv:FILE
  benchmark_inference --dataset=csv:FILE --model=MODEL.json|MODEL.bin [--runs=20]
  serve            --model=[NAME=]MODEL.json|.bin[,flush_rows=N][,max_delay_ms=N][,score_threads=N]
                   [--model=NAME2=OTHER.json ...]
                   [--addr=127.0.0.1] [--port=8123] [--workers=4]
                   [--flush-rows=64] [--max-delay-ms=2]
                   [--max-queue-rows=4096] [--score-threads=0]
                   [--conn-timeout=60] [--queue-deadline-ms=1000]
                   [--quota-rows=0] [--admission-rows=0] [--trace=FILE]
                   [--calibrate=off|load|force]
                   (--model repeats to serve several models from one
                    port; the first is the default route. NAME defaults
                    to the file stem. Trailing ,key=value pairs on a
                    --model value override the global batching policy
                    for that model only (keys: flush_rows, max_delay_ms,
                    score_threads). --score-threads: workers a large
                    coalesced flush fans out over; 0 = auto, 1 = serial.
                    --conn-timeout: seconds before an idle/stalled
                    connection is reaped, 0 = never. --queue-deadline-ms:
                    shed requests queued longer than this with a
                    retryable error, 0 = never shed. --quota-rows:
                    per-model pending-row cap; --admission-rows: shared
                    pending-row budget across all models; 0 = off.
                    Models hot-reload while serving via the load/swap/
                    unload admin commands, docs/serving.md.
                    --calibrate: engine routing per batch size —
                    "load" (default) uses/creates the cached
                    calibration table next to each model file, "force"
                    re-measures, "off" pins the static engine order.
                    --trace:
                    record request/flush spans, written as Chrome
                    trace-event JSON when the server stops; the metrics
                    wire command exposes Prometheus text exposition,
                    docs/observability.md)
  route            --backend=HOST:PORT [--backend=HOST:PORT ...]
                   [--addr=127.0.0.1] [--port=8200] [--workers=4]
                   [--replicas=0] [--retry-budget=3]
                   [--probe-interval-ms=1000] [--connect-timeout-ms=2000]
                   [--hop-timeout-ms=10000] [--backoff-base-ms=10]
                   [--backoff-cap-ms=500] [--conn-timeout=60]
                   (fleet routing tier: one endpoint over N `ydf serve`
                    backends, speaking the same wire protocol. Requests
                    place by rendezvous hashing on the \"model\" field
                    onto per-model replica sets (--replicas; 0 = auto =
                    min(2, backends)); backends are health-probed every
                    --probe-interval-ms and transport failures retry on
                    the next replica with exponential backoff under
                    --retry-budget. When every replica of a model is
                    down, requests are shed in band with
                    {{\"retryable\": true, \"retry_after_ms\": N}}.
                    drain/undrain admin commands remove/re-admit a
                    backend with zero dropped requests. docs/serving.md,
                    \"Fleet routing\")
  synth            --name=TABLE5_NAME --output=csv:FILE [--max-examples=N]
  benchmark_suite  [--full] [--folds=N] [--trees=N] [--trials=N]
                   [--datasets=a,b,c] [--max-examples=N]

Registered learners: GRADIENT_BOOSTED_TREES, RANDOM_FOREST, CART, LINEAR.
Hyper-parameter template: --param:template=benchmark_rank1@v1"
    );
    std::process::exit(2);
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    for a in args {
        if let Some(rest) = a.strip_prefix("--") {
            match rest.split_once('=') {
                Some((k, v)) => out.insert(k.to_string(), v.to_string()),
                None => out.insert(rest.to_string(), "true".to_string()),
            };
        } else {
            eprintln!("unexpected argument '{a}' (flags are --key=value)");
            std::process::exit(2);
        }
    }
    out
}

fn req<'a>(flags: &'a HashMap<String, String>, key: &str) -> &'a str {
    match flags.get(key) {
        Some(v) => v,
        None => {
            eprintln!("missing required flag --{key}=...");
            std::process::exit(2);
        }
    }
}

/// Parses "csv:path" dataset designators (the paper's CLI syntax).
fn dataset_path(designator: &str) -> PathBuf {
    match designator.split_once(':') {
        Some(("csv", path)) => PathBuf::from(path),
        Some((fmt, _)) => {
            eprintln!("unsupported dataset format '{fmt}' (supported: csv)");
            std::process::exit(2);
        }
        None => PathBuf::from(designator),
    }
}

fn load_dataset(designator: &str) -> ydf::dataset::Dataset {
    let path = dataset_path(designator);
    read_csv_file(&path, &InferenceOptions::default()).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(1);
    })
}

fn ok_or_die<T>(r: Result<T, String>) -> T {
    r.unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(1);
    })
}

/// `--trace=FILE`: turns span recording on now and returns the target
/// path; the caller writes the file once its command finishes (see
/// `docs/observability.md` for the span vocabulary).
fn trace_flag(flags: &HashMap<String, String>) -> Option<PathBuf> {
    flags.get("trace").map(|p| {
        if p == "true" {
            eprintln!("--trace needs a file path: --trace=FILE");
            std::process::exit(2);
        }
        ydf::obs::trace::enable();
        PathBuf::from(p)
    })
}

fn write_trace(path: &Path) {
    match ydf::obs::trace::write_file(path) {
        Ok(events) => println!("wrote {events} trace event(s) to {}", path.display()),
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(1);
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match args.split_first() {
        Some((c, r)) => (c.as_str(), r),
        None => usage(),
    };
    let flags = parse_flags(rest);
    match cmd {
        "infer_dataspec" => {
            let ds = load_dataset(req(&flags, "dataset"));
            let out = req(&flags, "output");
            std::fs::write(out, ds.spec.to_json().to_string_pretty()).unwrap_or_else(|e| {
                eprintln!("cannot write {out}: {e}");
                std::process::exit(1);
            });
            println!("wrote dataspec ({} columns) to {out}", ds.spec.columns.len());
        }
        "show_dataspec" => {
            let path = req(&flags, "dataspec");
            let text = ok_or_die(
                std::fs::read_to_string(path)
                    .map_err(|e| format!("cannot read dataspec file {path}: {e}")),
            );
            let spec = ok_or_die(DataSpec::from_json(&ok_or_die(
                Json::parse(&text).map_err(|e| e.to_string()),
            )));
            let rows = flags
                .get("dataset")
                .map(|d| load_dataset(d).num_rows())
                .unwrap_or(0);
            println!("{}", spec.describe(rows));
        }
        "train" => {
            let ds = load_dataset(req(&flags, "dataset"));
            let label = req(&flags, "label");
            let learner_name = req(&flags, "learner");
            let mut params: HashMap<String, String> = flags
                .iter()
                .filter_map(|(k, v)| k.strip_prefix("param:").map(|p| (p.to_string(), v.clone())))
                .collect();
            // --threads is sugar for --param:num_threads (validated here so
            // the error names the flag, not the hyper-parameter).
            if let Some(t) = flags.get("threads") {
                ok_or_die(
                    t.parse::<usize>()
                        .ok()
                        .filter(|&t| t >= 1)
                        .ok_or_else(|| {
                            format!("--threads must be a positive integer, got '{t}'")
                        }),
                );
                params.insert("num_threads".to_string(), t.clone());
            }
            let learner = ok_or_die(create_learner(learner_name, label, &params));
            let trace_path = trace_flag(&flags);
            let t0 = std::time::Instant::now();
            let model = ok_or_die(learner.train(&ds));
            let out = req(&flags, "output");
            ok_or_die(save_model(model.as_ref(), Path::new(out)));
            println!(
                "trained {} on {} examples in {:.2}s -> {out}",
                learner_name,
                ds.num_rows(),
                t0.elapsed().as_secs_f64()
            );
            if let Some(p) = trace_path {
                write_trace(&p);
            }
        }
        "compile" => {
            let model_path = req(&flags, "model");
            let model = ok_or_die(load_model(Path::new(model_path)));
            let forest =
                ok_or_die(ydf::inference::compiled::CompiledForest::lower(model.as_ref()));
            let out = req(&flags, "output");
            ok_or_die(forest.write_artifact(Path::new(out)));
            let bytes = std::fs::metadata(out).map(|m| m.len()).unwrap_or(0);
            println!(
                "compiled {} ({} trees, {} nodes) -> {out} ({bytes} bytes, format v{})",
                model_path,
                forest.num_trees(),
                forest.num_nodes(),
                ydf::inference::compiled::ARTIFACT_VERSION
            );
        }
        "show_model" => {
            let model = ok_or_die(load_model(Path::new(req(&flags, "model"))));
            println!("{}", model.describe());
        }
        "evaluate" => {
            let ds = load_dataset(req(&flags, "dataset"));
            let model = ok_or_die(load_model(Path::new(req(&flags, "model"))));
            let label = model.spec().columns[model.label_col()].name.clone();
            let ev = ok_or_die(ydf::evaluation::evaluate_model(model.as_ref(), &ds, &label));
            println!("{}", ev.report());
        }
        "predict" => {
            let ds = load_dataset(req(&flags, "dataset"));
            let model = ok_or_die(load_model(Path::new(req(&flags, "model"))));
            // Batch path: fastest compatible engine over columnar storage.
            let (flat, dim) = ydf::inference::predict_flat(model.as_ref(), &ds);
            let probs: Vec<Vec<f64>> = flat.chunks(dim).map(|c| c.to_vec()).collect();
            let out_path = dataset_path(req(&flags, "output"));
            let mut file = std::fs::File::create(&out_path).unwrap();
            let classes = model.class_names();
            let names =
                if classes.is_empty() { vec!["prediction".to_string()] } else { classes };
            ydf::dataset::csv::write_predictions_csv(&mut file, &names, &probs).unwrap();
            println!("wrote {} predictions to {}", probs.len(), out_path.display());
        }
        "benchmark_inference" => {
            let ds = load_dataset(req(&flags, "dataset"));
            let model = ok_or_die(load_model(Path::new(req(&flags, "model"))));
            let runs: usize = flags.get("runs").map(|v| v.parse().unwrap()).unwrap_or(20);
            println!(
                "{}",
                ydf::inference::benchmark_inference_report(model.as_ref(), &ds, runs)
            );
        }
        "serve" => {
            // --model repeats: re-scan the raw args (parse_flags keeps
            // only the last occurrence of a key). Each value is
            // `name=path` or a bare path (name = the file stem).
            let model_flags: Vec<&str> = rest
                .iter()
                .filter_map(|a| a.strip_prefix("--model="))
                .collect();
            if model_flags.is_empty() {
                eprintln!("missing required flag --model=[NAME=]MODEL.json");
                std::process::exit(2);
            }
            let parse_usize = |key: &str, default: usize| -> usize {
                flags.get(key).map_or(default, |v| {
                    ok_or_die(v.parse::<usize>().map_err(|_| {
                        format!("--{key} must be a non-negative integer, got '{v}'")
                    }))
                })
            };
            let addr = flags.get("addr").map(|s| s.as_str()).unwrap_or("127.0.0.1");
            let port = parse_usize("port", 8123);
            let max_delay_ms = flags.get("max-delay-ms").map_or(2.0, |v| {
                ok_or_die(
                    v.parse::<f64>()
                        .ok()
                        .filter(|d| d.is_finite() && *d >= 0.0)
                        .ok_or_else(|| {
                            format!(
                                "--max-delay-ms must be a non-negative number of \
                                 milliseconds, got '{v}'"
                            )
                        }),
                )
            });
            let batcher = ydf::serving::BatcherConfig {
                flush_rows: parse_usize("flush-rows", ydf::inference::BLOCK_SIZE),
                max_delay: std::time::Duration::from_secs_f64(max_delay_ms / 1e3),
                max_queue_rows: parse_usize("max-queue-rows", 4096),
                score_threads: parse_usize("score-threads", 0),
                queue_deadline: std::time::Duration::from_millis(
                    parse_usize("queue-deadline-ms", 1000) as u64,
                ),
                quota_rows: parse_usize("quota-rows", 0),
                admission_rows: parse_usize("admission-rows", 0),
            };
            // --calibrate=off|load|force (default load): off pins the
            // static engine order; load uses the cached per-batch-size
            // calibration table next to each model (measuring and
            // caching on a miss); force re-measures and rewrites it.
            let calibrate = flags.get("calibrate").map_or(
                ydf::inference::router::CalibrateMode::Load,
                |v| {
                    ok_or_die(ydf::inference::router::CalibrateMode::parse(v).ok_or_else(
                        || format!("--calibrate must be off, load or force, got '{v}'"),
                    ))
                },
            );
            // Splits a --model path value's trailing `,key=value` batching
            // overrides (keys: flush_rows, max_delay_ms, score_threads)
            // off the actual path. A value naming an existing file is
            // served verbatim (real paths may contain commas); unknown
            // keys or unparsable values are rejected loudly at startup.
            let split_model_options = |raw: &str| -> (String, Option<ydf::serving::BatcherConfig>) {
                if Path::new(raw).is_file() || !raw.contains(',') {
                    return (raw.to_string(), None);
                }
                let mut parts = raw.split(',');
                let path = parts.next().unwrap_or(raw).to_string();
                let mut cfg = batcher.clone();
                for opt in parts {
                    let Some((key, value)) = opt.split_once('=') else {
                        eprintln!(
                            "bad --model option '{opt}': expected key=value \
                             (keys: flush_rows, max_delay_ms, score_threads)"
                        );
                        std::process::exit(2);
                    };
                    let parsed = value.parse::<usize>().unwrap_or_else(|_| {
                        eprintln!(
                            "bad --model option '{opt}': '{value}' is not a \
                             non-negative integer"
                        );
                        std::process::exit(2);
                    });
                    match key {
                        "flush_rows" => cfg.flush_rows = parsed,
                        "max_delay_ms" => {
                            cfg.max_delay = std::time::Duration::from_millis(parsed as u64)
                        }
                        "score_threads" => cfg.score_threads = parsed,
                        _ => {
                            eprintln!(
                                "unknown --model option '{key}' (known keys: \
                                 flush_rows, max_delay_ms, score_threads)"
                            );
                            std::process::exit(2);
                        }
                    }
                }
                (path, Some(cfg))
            };
            let registry = ydf::serving::Registry::new(batcher.clone());
            for m in model_flags {
                // `name=path`, where a name is a plain identifier. Two
                // escape hatches keep the single-model form backward
                // compatible for paths that themselves contain '=': a
                // prefix with a path separator (--model=/data/run=3/m.json)
                // is never a name, and a value naming an existing file
                // (--model=run=1.json) is served verbatim as that file.
                let (name, rawpath) = match m.split_once('=') {
                    Some((n, p))
                        if !n.contains('/')
                            && !n.contains('\\')
                            && !Path::new(m).is_file() =>
                    {
                        (Some(n.to_string()), p)
                    }
                    _ => (None, m),
                };
                let (path, override_cfg) = split_model_options(rawpath);
                let path = path.as_str();
                // The default name is the *path's* file stem — computed
                // after the option split so `,flush_rows=8` never leaks
                // into a model name.
                let name = name.unwrap_or_else(|| {
                    Path::new(path)
                        .file_stem()
                        .map(|s| s.to_string_lossy().into_owned())
                        .unwrap_or_else(|| "default".to_string())
                });
                if let Some(cfg) = override_cfg {
                    println!(
                        "model '{name}': batching override (flush_rows={}, \
                         max_delay_ms={}, score_threads={})",
                        cfg.flush_rows,
                        cfg.max_delay.as_millis(),
                        cfg.score_threads
                    );
                    registry.set_model_config(&name, cfg);
                }
                let session =
                    ok_or_die(ydf::serving::Session::open_with(Path::new(path), calibrate));
                println!(
                    "model '{}': {} ({} -> {} outputs, calibration {})",
                    name,
                    path,
                    session.model().model_type(),
                    session.output_dim(),
                    if session.router_calibrated() { "measured" } else { "static" }
                );
                ok_or_die(registry.register(&name, session));
            }
            let conn_timeout_s = parse_usize("conn-timeout", 60);
            let config = ydf::serving::ServerConfig {
                addr: format!("{addr}:{port}"),
                workers: parse_usize("workers", 4),
                // 0 = never reap; otherwise seconds of socket silence
                // before an idle or stalled connection is closed.
                conn_timeout: (conn_timeout_s > 0)
                    .then(|| std::time::Duration::from_secs(conn_timeout_s as u64)),
                // Hot reloads (load/swap) open sessions under the same
                // calibration policy as the boot-time --model flags.
                calibrate,
                ..Default::default()
            };
            println!("protocol: newline-delimited JSON (docs/serving.md)");
            let trace_path = trace_flag(&flags);
            ok_or_die(ydf::serving::serve(registry, &config));
            if let Some(p) = trace_path {
                write_trace(&p);
            }
        }
        "route" => {
            // --backend repeats: re-scan the raw args, same as --model.
            let backends: Vec<String> = rest
                .iter()
                .filter_map(|a| a.strip_prefix("--backend="))
                .map(|s| s.to_string())
                .collect();
            if backends.is_empty() {
                eprintln!("missing required flag --backend=HOST:PORT (repeat for a fleet)");
                std::process::exit(2);
            }
            let parse_usize = |key: &str, default: usize| -> usize {
                flags.get(key).map_or(default, |v| {
                    ok_or_die(v.parse::<usize>().map_err(|_| {
                        format!("--{key} must be a non-negative integer, got '{v}'")
                    }))
                })
            };
            let addr = flags.get("addr").map(|s| s.as_str()).unwrap_or("127.0.0.1");
            let port = parse_usize("port", 8200);
            let conn_timeout_s = parse_usize("conn-timeout", 60);
            let defaults = ydf::serving::RouteConfig::default();
            let config = ydf::serving::RouteConfig {
                addr: format!("{addr}:{port}"),
                workers: parse_usize("workers", defaults.workers),
                backends,
                conn_timeout: (conn_timeout_s > 0)
                    .then(|| std::time::Duration::from_secs(conn_timeout_s as u64)),
                connect_timeout: std::time::Duration::from_millis(parse_usize(
                    "connect-timeout-ms",
                    defaults.connect_timeout.as_millis() as usize,
                ) as u64),
                hop_timeout: std::time::Duration::from_millis(parse_usize(
                    "hop-timeout-ms",
                    defaults.hop_timeout.as_millis() as usize,
                ) as u64),
                probe_interval: std::time::Duration::from_millis(parse_usize(
                    "probe-interval-ms",
                    defaults.probe_interval.as_millis() as usize,
                ) as u64),
                retry_budget: parse_usize("retry-budget", defaults.retry_budget),
                backoff_base_ms: parse_usize("backoff-base-ms", defaults.backoff_base_ms as usize)
                    as u64,
                backoff_cap_ms: parse_usize("backoff-cap-ms", defaults.backoff_cap_ms as usize)
                    as u64,
                replicas: parse_usize("replicas", 0),
                ..Default::default()
            };
            println!("protocol: newline-delimited JSON (docs/serving.md, \"Fleet routing\")");
            ok_or_die(ydf::serving::route(&config));
        }
        "synth" => {
            let name = req(&flags, "name");
            let spec = synthetic::spec_by_name(name).unwrap_or_else(|| {
                eprintln!("unknown dataset '{name}'. See Table 5 (DESIGN.md) for names.");
                std::process::exit(2);
            });
            let mut opts = synthetic::GenOptions::default();
            if let Some(m) = flags.get("max-examples") {
                opts.max_examples = m.parse().unwrap();
            }
            let ds = synthetic::generate(spec, 20230806, &opts);
            let out_path = dataset_path(req(&flags, "output"));
            std::fs::write(&out_path, write_csv_string(&ds)).unwrap();
            println!("wrote {} rows to {}", ds.num_rows(), out_path.display());
        }
        "benchmark_suite" => {
            let mut config = if flags.contains_key("full") {
                ydf::benchmark::SuiteConfig::full()
            } else {
                ydf::benchmark::SuiteConfig::default()
            };
            if let Some(f) = flags.get("folds") {
                config.folds = f.parse().unwrap();
            }
            if let Some(t) = flags.get("trees") {
                config.scale.num_trees = t.parse().unwrap();
            }
            if let Some(t) = flags.get("trials") {
                config.scale.tuner_trials = t.parse().unwrap();
            }
            if let Some(m) = flags.get("max-examples") {
                config.max_examples = m.parse().unwrap();
            }
            if let Some(d) = flags.get("datasets") {
                config.datasets = d
                    .split(',')
                    .map(|n| {
                        synthetic::spec_by_name(n.trim())
                            .unwrap_or_else(|| {
                                eprintln!("unknown dataset '{n}'");
                                std::process::exit(2);
                            })
                            .name
                    })
                    .collect();
            }
            let result = ydf::benchmark::run_suite(&config, |line| eprintln!("{line}"));
            println!("{}", result.fig6_report());
            println!("{}", result.table2_report());
            println!("{}", result.table3_report());
            println!("{}", result.table4_report());
            println!("{}", ydf::benchmark::table5_report());
            println!("{}", result.time_table_report(false));
            println!("{}", result.time_table_report(true));
        }
        _ => usage(),
    }
}
