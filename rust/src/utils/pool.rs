//! A small scoped thread pool (rayon is unavailable offline).
//!
//! Used by the Random Forest learner (per-tree parallelism), the distributed
//! backend and the serving example. Work items are closures; `scope_map`
//! offers the common "parallel map over indices" pattern.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Runs `f(i)` for `i in 0..n` across `threads` OS threads and returns the
/// results in index order. Falls back to sequential execution when
/// `threads <= 1` (the common case on this single-core testbed).
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let threads = threads.min(n);
    let next = AtomicUsize::new(0);
    let results: Vec<Mutex<Option<T>>> = (0..n).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let out = f(i);
                *results[i].lock().unwrap() = Some(out);
            });
        }
    });
    results
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker did not produce a result"))
        .collect()
}

/// Long-lived worker pool with explicit job submission; used by the
/// distributed backend to model persistent training workers.
pub struct WorkerPool {
    senders: Vec<std::sync::mpsc::Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl WorkerPool {
    pub fn new(workers: usize) -> WorkerPool {
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = std::sync::mpsc::channel::<Job>();
            senders.push(tx);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("ydf-worker-{w}"))
                    .spawn(move || {
                        while let Ok(job) = rx.recv() {
                            job();
                        }
                    })
                    .expect("failed to spawn worker"),
            );
        }
        WorkerPool { senders, handles }
    }

    pub fn num_workers(&self) -> usize {
        self.senders.len()
    }

    /// Submits a job to a specific worker (the feature-parallel algorithm
    /// pins features to workers, so placement matters).
    pub fn submit_to<F: FnOnce() + Send + 'static>(&self, worker: usize, f: F) {
        self.senders[worker].send(Box::new(f)).expect("worker channel closed");
    }

    /// Runs `f(w)` on every worker and blocks until all complete.
    pub fn broadcast<F>(&self, f: F)
    where
        F: Fn(usize) + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
        for w in 0..self.senders.len() {
            let f = Arc::clone(&f);
            let done = done_tx.clone();
            self.submit_to(w, move || {
                f(w);
                let _ = done.send(());
            });
        }
        for _ in 0..self.senders.len() {
            done_rx.recv().expect("worker died");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.senders.clear(); // close channels, letting workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_sequential_fallback() {
        let out = parallel_map(5, 1, |i| i + 1);
        assert_eq!(out, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn worker_pool_broadcast_touches_all() {
        let pool = WorkerPool::new(3);
        static COUNT: AtomicU64 = AtomicU64::new(0);
        pool.broadcast(|_w| {
            COUNT.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(COUNT.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn worker_pool_submit_to_runs() {
        let pool = WorkerPool::new(2);
        let (tx, rx) = std::sync::mpsc::channel();
        pool.submit_to(1, move || tx.send(42).unwrap());
        assert_eq!(rx.recv().unwrap(), 42);
    }
}
