//! Multi-model serving registry: several named models behind one server,
//! hot-reloadable while serving.
//!
//! The paper's serving story (§3.7, §5) is one library hosting many
//! models, each pinned to the fastest engine its structure compiles to.
//! A [`Registry`] owns N named [`Session`]s; each entry gets its **own**
//! [`Batcher`] (coalescing only same-model rows — batches must stay
//! single-dataspec so one flush is one `predict_batch`) and its own
//! [`ServingStats`]. Requests route by the top-level `"model"` field of
//! the wire protocol; requests without one go to the **default model**
//! (the first registered), which preserves the PR-3 single-model wire
//! protocol bit for bit.
//!
//! All batchers share one scoring [`WorkerPool`] (resolved from
//! [`BatcherConfig::score_threads`]): flushes larger than one kernel
//! block fan their block spans out across it, so a 512-row coalesced
//! flush no longer scores on one thread — and N models do not multiply
//! the scoring-thread count. When [`BatcherConfig::admission_rows`] is
//! set, all batchers also share one [`AdmissionControl`] budget.
//!
//! # Control plane
//!
//! The registry is mutable while serving: [`Registry::load`] adds a
//! model, [`Registry::swap`] replaces one under an existing name, and
//! [`Registry::unload`] removes one — each an `&self` operation safe to
//! call from any connection worker. Every generation of every model
//! walks the lifecycle
//!
//! ```text
//! Loading -> Serving -> Draining -> Retired
//!        \-> Failed
//! ```
//!
//! A swap builds the incoming [`Session`] **without holding the registry
//! lock** (model builds take milliseconds to seconds; reads keep
//! resolving throughout), then atomically replaces the entry `Arc` at
//! the same registration index — the default route and per-model stats
//! (plus their `reloads` counter) carry over. The outgoing generation is
//! marked `Draining`, its batcher shut down (rejecting new submissions
//! while the drain pass answers everything already accepted — zero
//! in-flight requests dropped), and a detached drain thread marks it
//! `Retired` once [`Batcher::await_drained`] returns. In-flight
//! connections holding the old entry `Arc` finish their requests against
//! the old session; new resolutions see the new generation immediately.

use super::batcher::{AdmissionControl, Batcher};
use super::session::Session;
use super::stats::{aggregate_json, ServingStats};
use super::BatcherConfig;
use crate::utils::json::Json;
use crate::utils::pool::WorkerPool;
use std::collections::{HashMap, HashSet};
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, RwLock};

/// Lifecycle of one generation of one served model. Stored as an atomic
/// on the entry so readers never take the registry lock to inspect it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lifecycle {
    /// The incoming session is being built; not yet routable.
    Loading = 0,
    /// Live: resolvable and scoring.
    Serving = 1,
    /// Swapped out or unloaded; no longer resolvable, still answering
    /// the requests it had accepted.
    Draining = 2,
    /// Fully drained; every accepted request was answered.
    Retired = 3,
    /// The load never went live (bad path, corrupt model, name race).
    Failed = 4,
}

impl Lifecycle {
    pub fn name(self) -> &'static str {
        match self {
            Lifecycle::Loading => "Loading",
            Lifecycle::Serving => "Serving",
            Lifecycle::Draining => "Draining",
            Lifecycle::Retired => "Retired",
            Lifecycle::Failed => "Failed",
        }
    }

    fn from_u8(x: u8) -> Lifecycle {
        match x {
            0 => Lifecycle::Loading,
            1 => Lifecycle::Serving,
            2 => Lifecycle::Draining,
            3 => Lifecycle::Retired,
            _ => Lifecycle::Failed,
        }
    }
}

/// One served model generation: a session pinned to its engine, the
/// batcher that coalesces its requests, its telemetry, and its lifecycle
/// state. Handed out as an `Arc` snapshot — an entry stays fully usable
/// (scoring, draining) after it is swapped out of the registry.
pub struct ModelEntry {
    name: String,
    /// Registry-unique, monotonically increasing: distinguishes the
    /// generations a name serves across swaps (connection scratch blocks
    /// key on it).
    generation: u64,
    session: Arc<Session>,
    batcher: Arc<Batcher>,
    stats: Arc<ServingStats>,
    state: Arc<AtomicU8>,
}

impl ModelEntry {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn generation(&self) -> u64 {
        self.generation
    }

    pub fn session(&self) -> &Arc<Session> {
        &self.session
    }

    pub fn batcher(&self) -> &Arc<Batcher> {
        &self.batcher
    }

    pub fn stats(&self) -> &Arc<ServingStats> {
        &self.stats
    }

    pub fn state(&self) -> Lifecycle {
        Lifecycle::from_u8(self.state.load(Ordering::SeqCst))
    }

    fn set_state(&self, s: Lifecycle) {
        self.state.store(s as u8, Ordering::SeqCst);
    }
}

/// A live view of one lifecycle record for the health report: the state
/// cell is shared with the entry (or failed ticket), so the log shows
/// `Draining` turning into `Retired` without bookkeeping.
struct Transition {
    name: String,
    generation: u64,
    state: Arc<AtomicU8>,
}

/// Recent lifecycle records kept for `{"cmd": "health"}`; oldest dropped
/// beyond this.
const TRANSITION_LOG_CAP: usize = 32;

struct Inner {
    /// Registration order; the first entry is the default route. A swap
    /// replaces in place (order preserved); an unload removes.
    entries: Vec<Arc<ModelEntry>>,
    by_name: HashMap<String, usize>,
}

impl Inner {
    fn reindex(&mut self) {
        self.by_name.clear();
        for (i, e) in self.entries.iter().enumerate() {
            self.by_name.insert(e.name.clone(), i);
        }
    }
}

/// In-progress load/swap handle from [`Registry::begin_load`]: the name
/// is reserved and a `Loading` record published. Finish with
/// [`Registry::complete_load`] or [`Registry::fail_load`]; dropping the
/// ticket unreserves the name and marks the record `Failed`.
pub struct LoadTicket {
    name: String,
    generation: u64,
    state: Arc<AtomicU8>,
    swap: bool,
    /// Present until complete/fail; its drop releases the name
    /// reservation.
    guard: Option<LoadGuard>,
}

struct LoadGuard {
    name: String,
    loading: Arc<Mutex<HashSet<String>>>,
}

impl Drop for LoadGuard {
    fn drop(&mut self) {
        let mut loading = match self.loading.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        loading.remove(&self.name);
    }
}

impl Drop for LoadTicket {
    fn drop(&mut self) {
        if self.guard.is_some() {
            // Abandoned without complete_load: the attempt failed.
            self.state.store(Lifecycle::Failed as u8, Ordering::SeqCst);
        }
    }
}

/// Named collection of serving sessions sharing one batching policy, one
/// scoring pool and (optionally) one admission budget. The first
/// registered model is the default route. All mutating operations take
/// `&self`: the registry is designed to be shared behind an `Arc` and
/// administered while serving.
pub struct Registry {
    inner: RwLock<Inner>,
    batcher_config: BatcherConfig,
    /// Shared across every entry's batcher; `None` when flushes score
    /// single-threaded (`score_threads` resolves to 1).
    score_pool: Option<Arc<WorkerPool>>,
    /// Shared pending-row budget across every entry's batcher; `None`
    /// when `admission_rows` is 0.
    admission: Option<Arc<AdmissionControl>>,
    /// Per-model batching-policy overrides (`set_model_config`), keyed by
    /// model name and applied at every load/swap of that name. The pool
    /// is resolved once at override time: an override keeping the global
    /// `score_threads` shares the registry pool, anything else gets its
    /// own (or none, when it resolves to single-threaded scoring).
    overrides: Mutex<HashMap<String, (BatcherConfig, Option<Arc<WorkerPool>>)>>,
    next_generation: AtomicU64,
    /// Names with a load/swap in flight (duplicate-admin guard).
    loading: Arc<Mutex<HashSet<String>>>,
    /// Recent lifecycle records, oldest first, bounded.
    transitions: Mutex<Vec<Transition>>,
}

impl Registry {
    /// An empty registry; `config` is applied to every model's batcher.
    /// The shared scoring pool is sized from `config.score_threads`
    /// (`0` = the `batch_threads()` default, `1` = no pool); the shared
    /// admission budget from `config.admission_rows` (`0` = none).
    pub fn new(config: BatcherConfig) -> Registry {
        let score_pool = config.resolve_score_pool();
        let admission =
            (config.admission_rows > 0).then(|| Arc::new(AdmissionControl::new(config.admission_rows)));
        Registry {
            inner: RwLock::new(Inner { entries: Vec::new(), by_name: HashMap::new() }),
            batcher_config: config,
            score_pool,
            admission,
            overrides: Mutex::new(HashMap::new()),
            next_generation: AtomicU64::new(1),
            loading: Arc::new(Mutex::new(HashSet::new())),
            transitions: Mutex::new(Vec::new()),
        }
    }

    /// Overrides the batching policy for one model *name*: every future
    /// load/swap of `name` builds its batcher from `config` instead of
    /// the registry-wide default (`--model=name=path,flush_rows=…` on the
    /// CLI). The admission budget stays shared — per-model overrides tune
    /// batching, they do not carve out private admission capacity. Set
    /// before `register`/`load`; an override installed later takes effect
    /// at the next swap of that name.
    pub fn set_model_config(&self, name: &str, config: BatcherConfig) {
        // Resolve the scoring pool once, here: an override that keeps the
        // global score_threads shares the registry pool (N overridden
        // models must not multiply scoring threads); a different value
        // gets its own resolution.
        let pool = if config.score_threads == self.batcher_config.score_threads {
            self.score_pool.clone()
        } else {
            config.resolve_score_pool()
        };
        let mut overrides = match self.overrides.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        overrides.insert(name.to_string(), (config, pool));
    }

    /// The batcher policy and scoring pool a load of `name` uses:
    /// the model's override when one is set, else the registry default.
    fn config_for(&self, name: &str) -> (BatcherConfig, Option<Arc<WorkerPool>>) {
        let overrides = match self.overrides.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        match overrides.get(name) {
            Some((c, p)) => (c.clone(), p.clone()),
            None => (self.batcher_config.clone(), self.score_pool.clone()),
        }
    }

    fn read(&self) -> std::sync::RwLockReadGuard<'_, Inner> {
        match self.inner.read() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    fn write(&self) -> std::sync::RwLockWriteGuard<'_, Inner> {
        match self.inner.write() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        }
    }

    fn log_transition(&self, name: &str, generation: u64, state: Arc<AtomicU8>) {
        let mut log = match self.transitions.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        if log.len() >= TRANSITION_LOG_CAP {
            log.remove(0);
        }
        log.push(Transition { name: name.to_string(), generation, state });
    }

    /// Registers `session` under `name`, spinning up its batcher (and
    /// scorer thread) immediately. Errors on an empty or duplicate name —
    /// misconfiguration reports what is wrong instead of silently
    /// shadowing an already-served model (§2.1). Sugar for
    /// [`Registry::load`] discarding the generation.
    pub fn register(&self, name: &str, session: Session) -> Result<(), String> {
        self.load(name, session).map(|_| ())
    }

    /// Adds a *new* model while serving; errors if `name` is taken.
    /// Returns the new generation number.
    pub fn load(&self, name: &str, session: Session) -> Result<u64, String> {
        let ticket = self.begin_load(name, false)?;
        self.complete_load(ticket, session)
    }

    /// Replaces the model behind an *existing* name while serving: the
    /// new session takes over the name (and its registration slot — a
    /// swapped default model stays the default), the old generation
    /// drains in the background with zero accepted requests dropped.
    /// Returns the new generation number.
    pub fn swap(&self, name: &str, session: Session) -> Result<u64, String> {
        let ticket = self.begin_load(name, true)?;
        self.complete_load(ticket, session)
    }

    /// Phase 1 of load/swap: validates the name, reserves it against
    /// concurrent admin operations, and publishes a `Loading` lifecycle
    /// record. The heavyweight session build then runs **without any
    /// registry lock held** (the server does it on the requesting
    /// connection's worker); finish with [`Registry::complete_load`] or
    /// [`Registry::fail_load`].
    pub fn begin_load(&self, name: &str, swap: bool) -> Result<LoadTicket, String> {
        if name.is_empty() {
            return Err("model name must not be empty".to_string());
        }
        {
            let inner = self.read();
            let exists = inner.by_name.contains_key(name);
            if swap && !exists {
                return Err(format!(
                    "cannot swap model '{name}': not registered. Registered models: {}.",
                    inner.entries.iter().map(|e| e.name.as_str()).collect::<Vec<_>>().join(", ")
                ));
            }
            if !swap && exists {
                return Err(format!(
                    "model '{name}' is already registered; model names must be unique \
                     (swap replaces a live model)"
                ));
            }
        }
        {
            let mut loading = match self.loading.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            if !loading.insert(name.to_string()) {
                return Err(format!("a load of model '{name}' is already in progress"));
            }
        }
        let generation = self.next_generation.fetch_add(1, Ordering::SeqCst);
        let state = Arc::new(AtomicU8::new(Lifecycle::Loading as u8));
        self.log_transition(name, generation, Arc::clone(&state));
        Ok(LoadTicket {
            name: name.to_string(),
            generation,
            state,
            swap,
            guard: Some(LoadGuard { name: name.to_string(), loading: Arc::clone(&self.loading) }),
        })
    }

    /// Phase 2 of load/swap: installs the built session under the
    /// ticket's name. The entry (and its batcher's scorer thread) is
    /// constructed outside the write lock; only the `Vec` slot swap
    /// happens under it. On swap, the outgoing generation starts
    /// draining in the background.
    pub fn complete_load(&self, mut ticket: LoadTicket, session: Session) -> Result<u64, String> {
        // Reuse the name's stats across generations: counters (and the
        // reloads count) describe the *name* clients route to, not one
        // generation.
        let prior = {
            let inner = self.read();
            inner.by_name.get(&ticket.name).map(|&i| Arc::clone(&inner.entries[i]))
        };
        let stats =
            prior.as_ref().map(|e| Arc::clone(e.stats())).unwrap_or_else(|| Arc::new(ServingStats::new()));
        let session = Arc::new(session);
        let (config, score_pool) = self.config_for(&ticket.name);
        let batcher = Arc::new(Batcher::with_admission(
            Arc::clone(&session),
            config,
            Arc::clone(&stats),
            score_pool,
            self.admission.clone(),
        ));
        let entry = Arc::new(ModelEntry {
            name: ticket.name.clone(),
            generation: ticket.generation,
            session,
            batcher,
            stats,
            state: Arc::clone(&ticket.state),
        });
        let old = {
            let mut inner = self.write();
            match inner.by_name.get(&ticket.name).copied() {
                Some(i) => {
                    if !ticket.swap {
                        // Unreachable while the loading-set reservation
                        // holds; keep a loud error rather than clobber.
                        drop(inner);
                        return Err(format!(
                            "model '{}' appeared while loading; use swap to replace it",
                            ticket.name
                        ));
                    }
                    Some(std::mem::replace(&mut inner.entries[i], entry))
                }
                None => {
                    if ticket.swap {
                        drop(inner);
                        return Err(format!(
                            "cannot swap model '{}': it was unloaded while the replacement \
                             was loading",
                            ticket.name
                        ));
                    }
                    let at = inner.entries.len();
                    inner.by_name.insert(ticket.name.clone(), at);
                    inner.entries.push(entry);
                    None
                }
            }
        };
        ticket.state.store(Lifecycle::Serving as u8, Ordering::SeqCst);
        ticket.guard = None; // release the name reservation, keep Serving
        if let Some(old) = old {
            old.stats().note_reload();
            self.log_transition(&old.name, old.generation, Arc::clone(&old.state));
            Self::drain_detached(old);
        }
        Ok(ticket.generation)
    }

    /// Phase 2 of a load that could not produce a session (bad path,
    /// corrupt file): marks the lifecycle record `Failed` and releases
    /// the name.
    pub fn fail_load(&self, ticket: LoadTicket) {
        drop(ticket); // LoadTicket::drop marks Failed and unreserves
    }

    /// Removes the model behind `name` while serving. The entry drains
    /// in the background (zero accepted requests dropped). Refuses to
    /// remove the last model — the server always has a default route.
    /// Returns the unloaded generation.
    pub fn unload(&self, name: &str) -> Result<u64, String> {
        {
            let loading = match self.loading.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            if loading.contains(name) {
                return Err(format!(
                    "a load of model '{name}' is in progress; retry after it settles"
                ));
            }
        }
        let old = {
            let mut inner = self.write();
            let Some(i) = inner.by_name.get(name).copied() else {
                return Err(format!(
                    "unknown model '{name}'. Registered models: {}.",
                    inner.entries.iter().map(|e| e.name.as_str()).collect::<Vec<_>>().join(", ")
                ));
            };
            if inner.entries.len() == 1 {
                return Err(format!(
                    "cannot unload '{name}': it is the last serving model (the server \
                     always keeps a default route); swap it instead"
                ));
            }
            let old = inner.entries.remove(i);
            inner.reindex();
            old
        };
        let generation = old.generation;
        self.log_transition(&old.name, generation, Arc::clone(&old.state));
        Self::drain_detached(old);
        Ok(generation)
    }

    /// Retires an outgoing generation off the caller's thread: shut the
    /// batcher down (new submissions rejected in-band), then wait for
    /// the drain pass to answer everything already accepted.
    fn drain_detached(old: Arc<ModelEntry>) {
        old.set_state(Lifecycle::Draining);
        old.batcher().shutdown();
        let handoff = Arc::clone(&old);
        let spawned = std::thread::Builder::new()
            .name("ydf-serving-drain".to_string())
            .spawn(move || {
                handoff.batcher().await_drained();
                handoff.set_state(Lifecycle::Retired);
            });
        if spawned.is_err() {
            // No thread to be had: drain inline rather than leave the
            // record stuck in Draining.
            old.batcher().await_drained();
            old.set_state(Lifecycle::Retired);
        }
    }

    pub fn len(&self) -> usize {
        self.read().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.read().entries.is_empty()
    }

    /// Registered model names, in registration order (the first is the
    /// default route).
    pub fn names(&self) -> Vec<String> {
        self.read().entries.iter().map(|e| e.name.clone()).collect()
    }

    /// The default model: the first registered (position is preserved by
    /// swaps and inherited on unload). Panics on an empty registry (the
    /// server refuses to start on one, and unload refuses to empty it).
    pub fn default_entry(&self) -> Arc<ModelEntry> {
        Arc::clone(self.read().entries.first().expect("registry has no models"))
    }

    pub fn get(&self, name: &str) -> Option<Arc<ModelEntry>> {
        let inner = self.read();
        inner.by_name.get(name).map(|&i| Arc::clone(&inner.entries[i]))
    }

    /// Snapshot of the entries in registration order. Owned `Arc`s: the
    /// caller's view stays valid (scoring, draining) even if a swap
    /// replaces an entry a microsecond later.
    pub fn entries(&self) -> Vec<Arc<ModelEntry>> {
        self.read().entries.iter().map(Arc::clone).collect()
    }

    /// Routes an optional request `"model"` field to an entry: `None`
    /// means the default model. Unknown names are a clean error listing
    /// what *is* registered — the server turns it into an in-band
    /// `{"error": …}` reply, never a dropped connection. A model that is
    /// `Draining`/`Retired` is no longer in the registry, so routing to
    /// it yields the same unknown-model error.
    pub fn resolve(&self, name: Option<&str>) -> Result<Arc<ModelEntry>, String> {
        let inner = self.read();
        match name {
            None => inner
                .entries
                .first()
                .map(Arc::clone)
                .ok_or_else(|| "no models are registered".to_string()),
            Some(n) => match inner.by_name.get(n) {
                Some(&i) => Ok(Arc::clone(&inner.entries[i])),
                None => Err(format!(
                    "unknown model '{n}'. Registered models: {}.",
                    inner.entries.iter().map(|e| e.name.as_str()).collect::<Vec<_>>().join(", ")
                )),
            },
        }
    }

    /// The shared admission budget, when configured.
    pub fn admission(&self) -> Option<&Arc<AdmissionControl>> {
        self.admission.as_ref()
    }

    /// `{"cmd": "health"}` fragment: each live model's lifecycle state.
    pub fn states_json(&self) -> Json {
        let mut j = Json::obj();
        for e in self.read().entries.iter() {
            j.set(&e.name, Json::Str(e.state().name().to_string()));
        }
        j
    }

    /// `{"cmd": "health"}` fragment: recent lifecycle records (loads,
    /// swaps, unloads — including `Draining`/`Retired`/`Failed`
    /// generations no longer in the registry), oldest first.
    pub fn transitions_json(&self) -> Json {
        let log = match self.transitions.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        Json::Arr(
            log.iter()
                .map(|t| {
                    let mut j = Json::obj();
                    j.set("model", Json::Str(t.name.clone()))
                        .set("generation", Json::Num(t.generation as f64))
                        .set(
                            "state",
                            Json::Str(
                                Lifecycle::from_u8(t.state.load(Ordering::SeqCst)).name().to_string(),
                            ),
                        );
                    j
                })
                .collect(),
        )
    }

    /// The `{"cmd": "stats"}` payload: aggregate counters at the top
    /// level (single-model shape preserved) plus a per-model breakdown
    /// under `"models"`.
    pub fn stats_json(&self) -> Json {
        let entries = self.entries();
        let named: Vec<(&str, &ServingStats)> =
            entries.iter().map(|e| (e.name.as_str(), e.stats.as_ref())).collect();
        let mut j = aggregate_json(&named);
        if let Some(admission) = &self.admission {
            let mut a = Json::obj();
            a.set("pending_rows", Json::Num(admission.pending_rows() as f64))
                .set("capacity", Json::Num(admission.capacity() as f64));
            j.set("admission", a);
        }
        j
    }

    /// The `{"cmd": "metrics"}` payload: the full Prometheus text
    /// exposition (format 0.0.4). Per-model serving families are rendered
    /// here from each entry's [`ServingStats`] snapshot — labeled
    /// `model="…"` — followed by the global `obs` registry (flush, pool,
    /// inference and training families). Latency is a `summary`:
    /// `quantile` series from the reservoir plus exact `_sum`/`_count`.
    pub fn prometheus(&self) -> String {
        use crate::obs::prom::{family_header, sample};
        let mut out = String::new();
        let entries = self.entries();
        // (name, help, kind, per-snapshot accessor) for the counter-shaped
        // serving families; one family header each, one sample per model.
        type Get = fn(&crate::serving::stats::StatsSnapshot) -> f64;
        let families: &[(&str, &str, &str, Get)] = &[
            ("ydf_serving_requests_total", "Requests answered successfully.", "counter",
             |s| s.requests as f64),
            ("ydf_serving_rows_total", "Rows scored across answered requests.", "counter",
             |s| s.rows as f64),
            ("ydf_serving_errors_total", "Requests answered with an in-band error.", "counter",
             |s| s.errors as f64),
            ("ydf_serving_rejected_total", "Submissions rejected by backpressure.", "counter",
             |s| s.rejected as f64),
            ("ydf_serving_shed_deadline_total", "Accepted requests shed by the queue deadline.",
             "counter", |s| s.shed_deadline as f64),
            ("ydf_serving_timed_out_connections_total", "Connections reaped by the idle timeout.",
             "counter", |s| s.timed_out_conns as f64),
            ("ydf_serving_overlong_lines_total",
             "Connections closed for a request line over max_line_bytes.", "counter",
             |s| s.overlong_lines as f64),
            ("ydf_serving_reloads_total", "Hot reloads (swaps) of the model.", "counter",
             |s| s.reloads as f64),
            ("ydf_serving_batches_total", "Coalesced batches scored.", "counter",
             |s| s.batches as f64),
            ("ydf_serving_batched_rows_total", "Rows scored through coalesced batches.", "counter",
             |s| s.batched_rows as f64),
            ("ydf_serving_queue_rows", "Rows currently queued for scoring.", "gauge",
             |s| s.queue_rows as f64),
            ("ydf_serving_queue_rows_peak", "High-water mark of queued rows.", "gauge",
             |s| s.queue_rows_peak as f64),
        ];
        let snapshots: Vec<_> = entries
            .iter()
            .map(|e| (e.name.as_str(), e.stats.snapshot()))
            .collect();
        for (name, help, kind, get) in families {
            family_header(&mut out, name, help, kind);
            for (model, snap) in &snapshots {
                sample(&mut out, name, &[("model", model)], get(snap));
            }
        }
        family_header(&mut out, "ydf_serving_generation", "Model generation (hot-reload counter).", "gauge");
        for e in &entries {
            sample(&mut out, "ydf_serving_generation", &[("model", e.name.as_str())],
                e.generation as f64);
        }
        family_header(
            &mut out,
            "ydf_serving_latency_us",
            "Request wall latency in microseconds (quantiles from a bounded uniform reservoir; sum/count exact).",
            "summary",
        );
        for e in &entries {
            let (count, mean, _min, _max, mut xs) = e.stats.latency_summary();
            xs.sort_by(|a, b| a.partial_cmp(b).expect("latencies are finite"));
            let model = e.name.as_str();
            for (q, p) in [("0.5", 0.50), ("0.95", 0.95), ("0.99", 0.99)] {
                let v = if xs.is_empty() {
                    0.0
                } else {
                    let rank = ((p * xs.len() as f64).ceil() as usize).clamp(1, xs.len());
                    xs[rank - 1]
                };
                sample(&mut out, "ydf_serving_latency_us", &[("model", model), ("quantile", q)], v);
            }
            sample(&mut out, "ydf_serving_latency_us_sum", &[("model", model)], mean * count as f64);
            sample(&mut out, "ydf_serving_latency_us_count", &[("model", model)], count as f64);
        }
        out.push_str(&crate::obs::prom::render_global());
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::learner::gbt::GbtConfig;
    use crate::learner::{GradientBoostedTreesLearner, Learner};
    use std::time::Duration;

    fn session(seed: u64, trees: usize) -> Session {
        let ds = synthetic::adult_like(200, seed);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = trees;
        cfg.max_depth = 3;
        Session::new(GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap())
    }

    fn one_row(e: &ModelEntry, age: f64) -> super::super::RowBlock {
        let mut block = e.session().new_block();
        let row = crate::utils::json::Json::parse(&format!(r#"{{"age": {age}}}"#)).unwrap();
        e.session().decode_row(&mut block, &row).unwrap();
        block
    }

    fn await_state(e: &ModelEntry, want: Lifecycle) {
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while e.state() != want {
            assert!(std::time::Instant::now() < deadline, "stuck in {:?}", e.state());
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    #[test]
    fn prometheus_exposition_covers_models_and_globals() {
        let r = Registry::new(BatcherConfig {
            max_delay: std::time::Duration::ZERO,
            ..Default::default()
        });
        r.register("promtest", session(7, 3)).unwrap();
        let e = r.get("promtest").unwrap();
        let block = one_row(&e, 44.0);
        e.batcher().submit(&block).unwrap().wait().unwrap();
        e.stats().note_request(1, 123.0);
        let text = r.prometheus();
        assert!(text.contains("# TYPE ydf_serving_requests_total counter"), "{text}");
        assert!(text.contains("ydf_serving_requests_total{model=\"promtest\"} 1"));
        assert!(text.contains("ydf_serving_latency_us{model=\"promtest\",quantile=\"0.5\"} 123"));
        assert!(text.contains("ydf_serving_latency_us_sum{model=\"promtest\"} 123"));
        assert!(text.contains("ydf_serving_latency_us_count{model=\"promtest\"} 1"));
        assert!(text.contains("# TYPE ydf_serving_latency_us summary"));
        // The global obs registry rides along — the flush this test's own
        // request just triggered guarantees the family exists.
        assert!(text.contains("# TYPE ydf_flush_total counter"));
        // Every non-comment line is `name[{labels}] value` with a parsable
        // value and a legal metric name.
        let mut samples = 0usize;
        for line in text.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
            let (name_part, value) = line.rsplit_once(' ').expect("sample line");
            assert!(value.parse::<f64>().is_ok(), "bad value: {line}");
            let name = name_part.split('{').next().unwrap();
            assert!(
                !name.is_empty()
                    && name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
                "bad name: {line}"
            );
            samples += 1;
        }
        assert!(samples > 0);
    }

    #[test]
    fn register_resolve_and_default() {
        let r = Registry::new(BatcherConfig {
            max_delay: std::time::Duration::ZERO,
            ..Default::default()
        });
        assert!(r.is_empty());
        r.register("a", session(1, 3)).unwrap();
        r.register("b", session(2, 4)).unwrap();
        assert_eq!(r.len(), 2);
        assert_eq!(r.names(), vec!["a", "b"]);
        assert_eq!(r.resolve(None).unwrap().name(), "a"); // first = default
        let b = r.resolve(Some("b")).unwrap();
        assert_eq!(b.name(), "b");
        assert_eq!(b.state(), Lifecycle::Serving);
        let err = r.resolve(Some("zzz")).unwrap_err();
        assert!(err.contains("zzz") && err.contains("a, b"), "{err}");
    }

    #[test]
    fn duplicate_and_empty_names_rejected() {
        let r = Registry::new(BatcherConfig::default());
        r.register("m", session(3, 3)).unwrap();
        assert!(r.register("m", session(4, 3)).unwrap_err().contains("already registered"));
        assert!(r.register("", session(5, 3)).unwrap_err().contains("empty"));
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn per_model_requests_route_to_their_own_batcher_and_stats() {
        let r = Registry::new(BatcherConfig {
            max_delay: std::time::Duration::ZERO,
            ..Default::default()
        });
        r.register("a", session(6, 3)).unwrap();
        r.register("b", session(7, 5)).unwrap();
        for (name, n) in [("a", 2usize), ("b", 3usize)] {
            let e = r.resolve(Some(name)).unwrap();
            for _ in 0..n {
                let block = one_row(&e, 33.0);
                let out = e.batcher().submit(&block).unwrap().wait().unwrap();
                assert_eq!(out.len(), e.session().output_dim());
                e.stats().note_request(1, 50.0);
            }
        }
        let j = r.stats_json();
        assert_eq!(j.req_f64("requests").unwrap(), 5.0);
        let models = j.req("models").unwrap();
        assert_eq!(models.req("a").unwrap().req_f64("requests").unwrap(), 2.0);
        assert_eq!(models.req("b").unwrap().req_f64("requests").unwrap(), 3.0);
        // Batches ran on each model's own batcher.
        assert!(models.req("a").unwrap().req_f64("batches").unwrap() >= 1.0);
        assert!(models.req("b").unwrap().req_f64("batches").unwrap() >= 1.0);
    }

    #[test]
    fn per_model_config_overrides_apply_at_load_and_survive_swap() {
        let r = Registry::new(BatcherConfig {
            max_delay: std::time::Duration::ZERO,
            ..Default::default()
        });
        // Override model 'a' to a 1-row queue before it is loaded; 'b'
        // keeps the registry-wide default.
        r.set_model_config(
            "a",
            BatcherConfig {
                max_delay: std::time::Duration::ZERO,
                max_queue_rows: 1,
                ..Default::default()
            },
        );
        r.register("a", session(41, 3)).unwrap();
        r.register("b", session(42, 3)).unwrap();
        let a = r.resolve(Some("a")).unwrap();
        let b = r.resolve(Some("b")).unwrap();
        assert_eq!(a.batcher().capacity_rows(), 1, "override applied to 'a'");
        assert_ne!(b.batcher().capacity_rows(), 1, "'b' keeps the default");

        // Observable behavior, not just the knob: a 2-row request can
        // never fit 'a''s queue, while 'b' takes it in stride.
        let two_rows = |e: &ModelEntry| {
            let mut block = e.session().new_block();
            for age in [30.0, 40.0] {
                let row =
                    crate::utils::json::Json::parse(&format!(r#"{{"age": {age}}}"#)).unwrap();
                e.session().decode_row(&mut block, &row).unwrap();
            }
            block
        };
        assert!(matches!(
            a.batcher().submit(&two_rows(&a)).unwrap_err(),
            crate::serving::SubmitError::RequestTooLarge { rows: 2, capacity: 1 }
        ));
        b.batcher().submit(&two_rows(&b)).unwrap().wait().unwrap();
        // One-row requests still flow through the overridden batcher.
        a.batcher().submit(&one_row(&a, 35.0)).unwrap().wait().unwrap();

        // The override is keyed by name: a swap of 'a' rebuilds its
        // batcher with the same per-model policy.
        r.swap("a", session(43, 2)).unwrap();
        let a2 = r.resolve(Some("a")).unwrap();
        assert_eq!(a2.batcher().capacity_rows(), 1);
        await_state(&a, Lifecycle::Retired);
    }

    #[test]
    fn unload_shifts_default_and_drains_accepted_requests() {
        // Flush unreachable: only the drain pass can answer the pending
        // request — proving unload drops nothing it accepted.
        let r = Registry::new(BatcherConfig {
            max_delay: Duration::from_secs(30),
            flush_rows: 1 << 20,
            ..Default::default()
        });
        r.register("a", session(10, 3)).unwrap();
        r.register("b", session(11, 4)).unwrap();
        let a = r.resolve(Some("a")).unwrap();
        let pending = a.batcher().submit(&one_row(&a, 40.0)).unwrap();

        let generation = r.unload("a").unwrap();
        assert_eq!(generation, a.generation());
        // The accepted request is still answered...
        assert_eq!(pending.wait().unwrap().len(), a.session().output_dim());
        // ...the old entry drains to Retired...
        await_state(&a, Lifecycle::Retired);
        // ...new submissions to the held entry are rejected in-band...
        assert!(matches!(
            a.batcher().submit(&one_row(&a, 41.0)),
            Err(crate::serving::SubmitError::Shutdown)
        ));
        // ...routing no longer finds it, and the default shifted to 'b'.
        assert!(r.resolve(Some("a")).unwrap_err().contains("unknown model"));
        assert_eq!(r.resolve(None).unwrap().name(), "b");
        // The last model is protected.
        let err = r.unload("b").unwrap_err();
        assert!(err.contains("last serving model"), "{err}");
        // The health log remembers the retired generation.
        let log = r.transitions_json().to_string();
        assert!(log.contains("Retired"), "{log}");
    }

    #[test]
    fn swap_replaces_session_preserves_slot_and_stats() {
        let r = Registry::new(BatcherConfig {
            max_delay: Duration::ZERO,
            ..Default::default()
        });
        r.register("m", session(20, 2)).unwrap();
        r.register("other", session(21, 3)).unwrap();
        let old = r.resolve(Some("m")).unwrap();
        old.stats().note_request(1, 10.0);
        let old_out = old.batcher().submit(&one_row(&old, 44.0)).unwrap().wait().unwrap();

        // Different seed and tree count: the replacement genuinely
        // disagrees with the old generation.
        let generation = r.swap("m", session(99, 8)).unwrap();
        let new = r.resolve(Some("m")).unwrap();
        assert!(generation > old.generation());
        assert_eq!(new.generation(), generation);
        assert_eq!(new.state(), Lifecycle::Serving);
        // Same registration slot: 'm' is still the default route.
        assert_eq!(r.resolve(None).unwrap().name(), "m");
        assert_eq!(r.names(), vec!["m", "other"]);
        // Stats carried over, and the swap was counted.
        let snap = new.stats().snapshot();
        assert_eq!(snap.requests, 1);
        assert_eq!(snap.reloads, 1);
        // The new generation scores (and disagrees with the old one).
        let new_out = new.batcher().submit(&one_row(&new, 44.0)).unwrap().wait().unwrap();
        assert_eq!(new_out.len(), new.session().output_dim());
        assert_ne!(old_out, new_out);
        // The old generation drains out.
        await_state(&old, Lifecycle::Retired);
        // Double-swap guard: a second swap of the same name works after
        // the first settled (the reservation was released).
        r.swap("m", session(100, 2)).unwrap();
    }

    #[test]
    fn begin_load_reserves_name_and_fail_load_records_failure() {
        let r = Registry::new(BatcherConfig::default());
        r.register("m", session(30, 2)).unwrap();
        let ticket = r.begin_load("incoming", false).unwrap();
        // Reserved: a concurrent load/swap/unload of the same name is
        // refused while the ticket is open.
        assert!(r.begin_load("incoming", false).unwrap_err().contains("in progress"));
        r.fail_load(ticket);
        let log = r.transitions_json().to_string();
        assert!(log.contains("Failed"), "{log}");
        // The name is free again...
        let ticket = r.begin_load("incoming", false).unwrap();
        r.complete_load(ticket, session(31, 2)).unwrap();
        assert_eq!(r.resolve(Some("incoming")).unwrap().state(), Lifecycle::Serving);
        // ...and invalid admin targets stay loud.
        assert!(r.begin_load("ghost", true).unwrap_err().contains("not registered"));
        assert!(r.unload("ghost").unwrap_err().contains("unknown model"));
    }
}
