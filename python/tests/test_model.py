"""L2 graph tests: forest_predict end-to-end semantics and the linear
fwd/bwd step, plus AOT lowering smoke checks."""

import numpy as np
import pytest

from compile import aot, model
from compile.kernels import forest as fk
from compile.kernels.ref import forest_traverse_ref, random_forest_tensors


def sigmoid(x):
    return 1.0 / (1.0 + np.exp(-x))


def test_forest_predict_matches_ref_pipeline():
    rng = np.random.default_rng(5)
    tensors = random_forest_tensors(
        rng, fk.MAX_TREES, fk.MAX_NODES, fk.MAX_FEATURES, max_depth=fk.MAX_DEPTH)
    nf, nt, npos, nneg, lv = tensors
    # Scale leaf values down so sigmoid stays in a testable range.
    lv = (lv * 0.05).astype(np.float32)
    features = rng.normal(size=(fk.BATCH, fk.MAX_FEATURES)).astype(np.float32)
    initial = np.array([-0.3], dtype=np.float32)
    (probs,) = model.forest_predict(features, nf, nt, npos, nneg, lv, initial)
    want_scores = initial[0] + forest_traverse_ref(
        features, nf, nt, npos, nneg, lv, fk.MAX_DEPTH).sum(axis=0)
    np.testing.assert_allclose(np.asarray(probs), sigmoid(want_scores), rtol=1e-5)


def test_linear_predict_softmax_normalized():
    rng = np.random.default_rng(9)
    x = rng.normal(size=(4, aot.LINEAR_DIM)).astype(np.float32)
    w = rng.normal(size=(aot.LINEAR_DIM, aot.LINEAR_CLASSES)).astype(np.float32)
    b = np.zeros(aot.LINEAR_CLASSES, dtype=np.float32)
    (probs,) = model.linear_predict(x, w, b)
    np.testing.assert_allclose(np.asarray(probs).sum(axis=1), np.ones(4), rtol=1e-5)


def test_linear_train_step_reduces_loss():
    rng = np.random.default_rng(13)
    x = rng.normal(size=(aot.LINEAR_BATCH, aot.LINEAR_DIM)).astype(np.float32)
    y = np.zeros((aot.LINEAR_BATCH, aot.LINEAR_CLASSES), dtype=np.float32)
    y[np.arange(aot.LINEAR_BATCH), rng.integers(0, aot.LINEAR_CLASSES,
                                                aot.LINEAR_BATCH)] = 1.0
    w = np.zeros((aot.LINEAR_DIM, aot.LINEAR_CLASSES), dtype=np.float32)
    b = np.zeros(aot.LINEAR_CLASSES, dtype=np.float32)
    lr = np.array([0.5], dtype=np.float32)
    losses = []
    for _ in range(10):
        w, b, loss = model.linear_train_step(x, y, w, b, lr)
        losses.append(float(loss))
    assert losses[-1] < losses[0], losses


@pytest.mark.parametrize("name", list(aot.ARTIFACTS))
def test_aot_lowering_emits_hlo_text(name):
    text = aot.to_hlo_text(aot.ARTIFACTS[name]())
    assert "HloModule" in text
    assert "ENTRY" in text
    # The interchange constraint: text form, parseable by XLA 0.5.1 — no
    # serialized-proto path anywhere.
    assert len(text) > 1000
