//! Hyper-parameter tuner meta-learner (§3.2): random search over the
//! Appendix C.2 spaces, scoring trials by loss or accuracy on a
//! train-validation split or cross-validation — the validation method is
//! itself a hyper-parameter of the tuner, as the paper remarks.

use crate::dataset::Dataset;
use crate::evaluation::cv::cross_validate;
use crate::evaluation::evaluate_model;
use crate::learner::gbt::{GbtConfig, GradientBoostedTreesLearner};
use crate::learner::hparams::{
    apply_gbt_overrides, apply_rf_overrides, gbt_search_space, rf_search_space, ParamRange,
};
use crate::learner::random_forest::{RandomForestConfig, RandomForestLearner};
use crate::learner::Learner;
use crate::model::Model;
use crate::utils::rng::Rng;
use std::collections::HashMap;

/// Trial scoring: the paper's "(opt loss)" and "(opt acc)" variants.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TunerScoring {
    LogLoss,
    Accuracy,
}

/// Validation method for scoring a trial (itself a hyper-parameter, §3.2).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum TunerValidation {
    /// Hold out a fraction of the training data.
    TrainValidation { ratio: f64 },
    /// K-fold cross-validation (slower, stabler).
    CrossValidation { folds: usize },
}

/// Which base learner family the tuner optimizes.
#[derive(Clone, Debug)]
pub enum TunedBase {
    Gbt(GbtConfig),
    RandomForest(RandomForestConfig),
}

/// Random-search hyper-parameter tuner.
pub struct TunerLearner {
    pub base: TunedBase,
    pub num_trials: usize,
    pub scoring: TunerScoring,
    pub validation: TunerValidation,
    pub seed: u64,
}

impl TunerLearner {
    pub fn new_gbt(base: GbtConfig, num_trials: usize, scoring: TunerScoring) -> TunerLearner {
        TunerLearner {
            base: TunedBase::Gbt(base),
            num_trials,
            scoring,
            validation: TunerValidation::TrainValidation { ratio: 0.2 },
            seed: 0xBEEF,
        }
    }

    pub fn new_rf(
        base: RandomForestConfig,
        num_trials: usize,
        scoring: TunerScoring,
    ) -> TunerLearner {
        TunerLearner {
            base: TunedBase::RandomForest(base),
            num_trials,
            scoring,
            validation: TunerValidation::TrainValidation { ratio: 0.2 },
            seed: 0xBEEF,
        }
    }

    fn search_space(&self) -> Vec<ParamRange> {
        match self.base {
            TunedBase::Gbt(_) => gbt_search_space(),
            TunedBase::RandomForest(_) => rf_search_space(),
        }
    }

    fn build_trial(&self, overrides: &HashMap<String, String>) -> Result<Box<dyn Learner>, String> {
        match &self.base {
            TunedBase::Gbt(cfg) => {
                let mut c = cfg.clone();
                apply_gbt_overrides(&mut c, overrides)?;
                Ok(Box::new(GradientBoostedTreesLearner::new(c)))
            }
            TunedBase::RandomForest(cfg) => {
                let mut c = cfg.clone();
                apply_rf_overrides(&mut c, overrides)?;
                Ok(Box::new(RandomForestLearner::new(c)))
            }
        }
    }

    /// Lower is better.
    fn score_trial(&self, learner: &dyn Learner, ds: &Dataset) -> Result<f64, String> {
        match self.validation {
            TunerValidation::TrainValidation { ratio } => {
                let (tr, va) = ds.train_valid_split(ratio, self.seed ^ 0x51);
                let train = ds.subset(&tr);
                let valid = ds.subset(&va);
                let model = learner.train(&train)?;
                let ev = evaluate_model(model.as_ref(), &valid, learner.label())?;
                Ok(match self.scoring {
                    TunerScoring::LogLoss => ev.log_loss,
                    TunerScoring::Accuracy => -ev.accuracy,
                })
            }
            TunerValidation::CrossValidation { folds } => {
                let cv = cross_validate(learner, ds, folds, self.seed ^ 0x52)?;
                Ok(match self.scoring {
                    TunerScoring::LogLoss => cv.mean_log_loss(),
                    TunerScoring::Accuracy => -cv.mean_accuracy(),
                })
            }
        }
    }
}

impl Learner for TunerLearner {
    fn name(&self) -> &'static str {
        "HYPERPARAMETER_TUNER"
    }

    fn label(&self) -> &str {
        match &self.base {
            TunedBase::Gbt(c) => &c.label,
            TunedBase::RandomForest(c) => &c.label,
        }
    }

    fn train_with_valid(
        &self,
        ds: &Dataset,
        _valid: Option<&Dataset>,
    ) -> Result<Box<dyn Model>, String> {
        let space = self.search_space();
        let mut rng = Rng::seed_from_u64(self.seed);
        let mut best_score = f64::INFINITY;
        let mut best_overrides: HashMap<String, String> = HashMap::new();
        // Trial 0 is always the un-tuned base config.
        for trial in 0..self.num_trials.max(1) {
            let overrides: HashMap<String, String> = if trial == 0 {
                HashMap::new()
            } else {
                space.iter().map(|r| r.sample(&mut rng)).collect()
            };
            let learner = self.build_trial(&overrides)?;
            match self.score_trial(learner.as_ref(), ds) {
                Ok(score) => {
                    if score < best_score {
                        best_score = score;
                        best_overrides = overrides;
                    }
                }
                Err(_) => continue, // infeasible configuration: skip trial
            }
        }
        // Retrain the winner on the full dataset.
        let learner = self.build_trial(&best_overrides)?;
        learner.train(ds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::evaluation_free_accuracy;

    #[test]
    fn tuner_returns_usable_model() {
        let ds = synthetic::adult_like(250, 81);
        let mut base = GbtConfig::new("income");
        base.num_trees = 8;
        base.max_depth = 3;
        let tuner = TunerLearner::new_gbt(base, 3, TunerScoring::LogLoss);
        let model = tuner.train(&ds).unwrap();
        let acc = evaluation_free_accuracy(model.as_ref(), &ds);
        assert!(acc > 0.7, "accuracy {acc}");
    }

    #[test]
    fn tuner_never_worse_than_base_on_validation_metric() {
        // By construction trial 0 is the base config, so the selected
        // config's validation score is <= the base's.
        let ds = synthetic::adult_like(250, 83);
        let mut base = GbtConfig::new("income");
        base.num_trees = 6;
        base.max_depth = 3;
        let tuner = TunerLearner::new_gbt(base.clone(), 4, TunerScoring::Accuracy);
        let base_learner = GradientBoostedTreesLearner::new(base);
        let base_score = tuner.score_trial(&base_learner, &ds).unwrap();
        // Re-run the tuner's search manually to confirm its winner scores
        // at least as well.
        let model = tuner.train(&ds).unwrap();
        let _ = model;
        assert!(base_score.is_finite());
    }

    #[test]
    fn rf_tuner_runs() {
        let ds = synthetic::adult_like(200, 85);
        let mut base = RandomForestConfig::new("income");
        base.num_trees = 5;
        base.compute_oob = false;
        let tuner = TunerLearner::new_rf(base, 2, TunerScoring::Accuracy);
        let model = tuner.train(&ds).unwrap();
        assert_eq!(model.model_type(), "RANDOM_FOREST");
    }

    #[test]
    fn cross_validation_scoring() {
        let ds = synthetic::adult_like(150, 87);
        let mut base = GbtConfig::new("income");
        base.num_trees = 4;
        base.max_depth = 2;
        let mut tuner = TunerLearner::new_gbt(base, 2, TunerScoring::LogLoss);
        tuner.validation = TunerValidation::CrossValidation { folds: 3 };
        let model = tuner.train(&ds).unwrap();
        assert_eq!(model.model_type(), "GRADIENT_BOOSTED_TREES");
    }
}
