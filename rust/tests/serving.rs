//! Serving-runtime integration tests: the batcher's coalescing is
//! bit-identical to one `predict_batch` over the same rows, the bounded
//! queue rejects instead of blocking, shutdown racing queue-full
//! submitters stays clean, two models served concurrently stay
//! bit-identical to their offline batch outputs, and the TCP server
//! answers the multi-model wire protocol end to end on a loopback socket.

mod common;

use common::{adult_json_rows, adult_session, decode_all};
use std::sync::Arc;
use std::time::Duration;
use ydf::inference::BLOCK_SIZE;
use ydf::serving::{Batcher, BatcherConfig, Registry, Session, SubmitError};
use ydf::utils::json::Json;

/// A trained adult-like session plus JSON rows for `n` requests covering
/// NaN/missing features and out-of-dictionary categoricals.
fn session_and_rows(n: usize, seed: u64) -> (Arc<Session>, Vec<String>) {
    (adult_session(400, seed, 6, 4), adult_json_rows(n))
}

/// N concurrent requests (mixed sizes, unaligned tails, NaN/missing and
/// OOD features) coalesced through the batcher must be bit-identical to
/// one `predict_batch` call over the same rows.
#[test]
fn concurrent_coalesced_requests_match_single_predict_batch() {
    // 201 rows: not a BLOCK_SIZE multiple, so tail blocks are exercised
    // both in the single reference call and inside coalesced batches.
    let (session, rows) = session_and_rows(201, 31);
    let mut reference_block = decode_all(&session, &rows);
    let reference = session.predict_block(&mut reference_block);
    let dim = session.output_dim();

    // Uneven request sizes (1, 8, 64, 3, ...) covering every row once.
    let sizes = [1usize, 8, 64, 3, 17, 2, 64, 5, 1, 9, 27];
    let mut requests: Vec<(usize, Vec<String>)> = Vec::new(); // (first row, rows)
    let mut at = 0usize;
    let mut k = 0usize;
    while at < rows.len() {
        let take = sizes[k % sizes.len()].min(rows.len() - at);
        requests.push((at, rows[at..at + take].to_vec()));
        at += take;
        k += 1;
    }

    for trial in 0..3 {
        let batcher = Batcher::new(
            Arc::clone(&session),
            BatcherConfig {
                // Vary the flush policy across trials: deadline-driven,
                // adaptive (drain-when-free), and threshold-driven. The
                // third trial also forces multi-threaded flush scoring.
                max_delay: Duration::from_micros([500, 0, 2000][trial]),
                flush_rows: [BLOCK_SIZE, BLOCK_SIZE, 2 * BLOCK_SIZE][trial],
                score_threads: [1, 1, 3][trial],
                ..Default::default()
            },
        );
        let results: Vec<(usize, usize, Vec<f64>)> = std::thread::scope(|s| {
            let handles: Vec<_> = requests
                .iter()
                .map(|(start, request_rows)| {
                    let session = &session;
                    let batcher = &batcher;
                    s.spawn(move || {
                        let block = decode_all(session, request_rows);
                        let out = batcher
                            .submit(&block)
                            .expect("queue sized for the test load")
                            .wait()
                            .expect("batcher scores every accepted request");
                        (*start, request_rows.len(), out)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (start, len, out) in results {
            assert_eq!(out.len(), len * dim);
            let expected = &reference[start * dim..(start + len) * dim];
            // Bit-identical, not approximately equal: coalescing must not
            // change a single bit of any prediction.
            assert_eq!(out.as_slice(), expected, "trial {trial}, rows {start}..{}", start + len);
        }
    }
}

/// A full bounded queue rejects new submissions immediately — it never
/// blocks the submitter — and the already-accepted requests still score.
#[test]
fn full_queue_rejects_instead_of_blocking() {
    let (session, rows) = session_and_rows(12, 47);
    let batcher = Batcher::new(
        Arc::clone(&session),
        BatcherConfig {
            // Flush can only happen via shutdown: threshold above capacity,
            // deadline far beyond the test's lifetime.
            flush_rows: BLOCK_SIZE,
            max_delay: Duration::from_secs(60),
            max_queue_rows: 10,
            ..Default::default()
        },
    );
    assert_eq!(batcher.capacity_rows(), 10);

    // Fill the queue to exactly its capacity with 5 two-row requests.
    let mut accepted = Vec::new();
    for chunk in rows.chunks(2).take(5) {
        let block = decode_all(&session, chunk);
        accepted.push(batcher.submit(&block).expect("queue has room"));
    }

    // The queue is full: the next submission is rejected, and quickly —
    // rejection is a return value, not a blocked thread.
    let extra = decode_all(&session, &rows[10..11]);
    let t0 = std::time::Instant::now();
    let err = batcher.submit(&extra).unwrap_err();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "rejection must be immediate, took {:?}",
        t0.elapsed()
    );
    assert_eq!(err, SubmitError::QueueFull { pending_rows: 10, capacity: 10 });
    assert_eq!(batcher.stats().snapshot().rejected, 1);

    // Shutdown drains the accepted requests; none is left hanging.
    drop(batcher);
    let dim = session.output_dim();
    for pending in accepted {
        assert_eq!(pending.wait().expect("drained on shutdown").len(), 2 * dim);
    }
}

/// Stress: submitters hammering a tiny queue (driving it into
/// `QueueFull`) racing an explicit shutdown. Every outcome must be clean
/// — accepted requests are drained and answered, rejected ones got an
/// immediate error, and after shutdown every submitter observes
/// `SubmitError::Shutdown`. No panic, no hang, no lost waiter.
#[test]
fn shutdown_races_queue_full_rejection() {
    let (session, rows) = session_and_rows(4, 59);
    let batcher = Arc::new(Batcher::new(
        Arc::clone(&session),
        BatcherConfig {
            // Unreachable flush threshold + far deadline: the queue fills
            // and stays full until the shutdown drain, so submitters are
            // bouncing off QueueFull at the moment shutdown lands.
            flush_rows: 64 * BLOCK_SIZE,
            max_delay: Duration::from_secs(60),
            max_queue_rows: 16,
            ..Default::default()
        },
    ));
    let dim = session.output_dim();
    let barrier = Arc::new(std::sync::Barrier::new(9));
    let mut handles = Vec::new();
    for t in 0..8u64 {
        let batcher = Arc::clone(&batcher);
        let session = Arc::clone(&session);
        let barrier = Arc::clone(&barrier);
        let row = rows[(t % 4) as usize].clone();
        handles.push(std::thread::spawn(move || {
            let block = decode_all(&session, &[row]);
            barrier.wait();
            let (mut accepted, mut full) = (0u32, 0u32);
            // Waiting is deferred: the queue only drains at shutdown, so
            // waiting inline would park every submitter after its first
            // accept and the queue would never fill.
            let mut pendings = Vec::new();
            loop {
                match batcher.submit(&block) {
                    Ok(pending) => {
                        accepted += 1;
                        pendings.push(pending);
                    }
                    Err(SubmitError::QueueFull { .. }) => full += 1,
                    Err(SubmitError::Shutdown) => break,
                    Err(e) => panic!("unexpected submit error: {e}"),
                }
                std::thread::yield_now();
            }
            for pending in pendings {
                // Accepted before shutdown: scored by the drain pass,
                // never left hanging.
                let out = pending.wait().expect("accepted requests are drained");
                assert_eq!(out.len(), dim);
            }
            (accepted, full)
        }));
    }
    barrier.wait();
    // Pull the plug only once the queue has demonstrably filled (a
    // rejection was recorded): the shutdown is then guaranteed to race
    // live queue-full bouncing, deterministically, on any scheduler.
    let t0 = std::time::Instant::now();
    while batcher.stats().snapshot().rejected == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(30),
            "queue never filled: submitters stalled"
        );
        std::thread::sleep(Duration::from_millis(1));
    }
    batcher.shutdown();
    let mut total_accepted = 0u32;
    let mut total_full = 0u32;
    for h in handles {
        let (a, f) = h.join().expect("no submitter panics");
        total_accepted += a;
        total_full += f;
    }
    // The 16-row queue accepted exactly its capacity in single-row
    // requests before jamming; everyone else bounced until shutdown.
    assert_eq!(total_accepted, 16, "accepted {total_accepted}");
    assert!(total_full > 0, "the queue never filled — the race never happened");
    assert_eq!(batcher.stats().snapshot().rejected as u32, total_full);
}

/// Two models served concurrently through one registry: interleaved
/// requests coalesce only with same-model rows, and every response is
/// bit-identical to that model's own single offline `predict_block`.
#[test]
fn two_models_served_concurrently_stay_bit_identical() {
    let rows = adult_json_rows(120);
    let registry = Registry::new(BatcherConfig {
        max_delay: Duration::from_micros(300),
        score_threads: 2,
        ..Default::default()
    });
    // Different seeds, tree counts and depths: two genuinely different
    // models behind one registry.
    registry.register("a", common::adult_session_owned(300, 61, 5, 4)).unwrap();
    registry.register("b", common::adult_session_owned(350, 67, 8, 3)).unwrap();
    // Offline references scored through the registry's own sessions —
    // the exact models the batchers will serve.
    let references: Vec<Vec<f64>> = ["a", "b"]
        .iter()
        .map(|name| {
            let entry = registry.resolve(Some(name)).unwrap();
            let mut block = decode_all(entry.session(), &rows);
            entry.session().predict_block(&mut block)
        })
        .collect();
    let registry = Arc::new(registry);

    // 8 clients, alternating models, each sending 15 eight-row requests.
    std::thread::scope(|scope| {
        for client in 0..8usize {
            let registry = Arc::clone(&registry);
            let rows = &rows;
            let references = &references;
            scope.spawn(move || {
                let model = client % 2;
                let name = if model == 0 { "a" } else { "b" };
                let entry = registry.resolve(Some(name)).unwrap();
                let dim = entry.session().output_dim();
                for req in 0..15usize {
                    let start = (client * 15 + req) * 8 % (rows.len() - 8);
                    let block = decode_all(entry.session(), &rows[start..start + 8]);
                    let out = entry.batcher().submit(&block).unwrap().wait().unwrap();
                    let expected = &references[model][start * dim..(start + 8) * dim];
                    assert_eq!(out.as_slice(), expected, "client {client} req {req}");
                }
            });
        }
    });
    let j = registry.stats_json();
    let models = j.req("models").unwrap();
    assert!(models.req("a").unwrap().req_f64("batches").unwrap() >= 1.0);
    assert!(models.req("b").unwrap().req_f64("batches").unwrap() >= 1.0);
}

/// End-to-end over loopback TCP: multi-model routing, per-model stats,
/// unknown-model errors on a surviving connection, malformed input, and
/// shutdown through the real server loop.
#[test]
fn tcp_server_round_trip() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    let registry = Registry::new(BatcherConfig {
        max_delay: Duration::ZERO,
        ..Default::default()
    });
    registry.register("alpha", common::adult_session_owned(200, 53, 3, 3)).unwrap();
    registry.register("beta", common::adult_session_owned(200, 54, 5, 3)).unwrap();

    // The stdout "listening on <addr>" contract is covered by the smoke
    // test; here we pre-bind to learn a free loopback port, release it,
    // and hand it to the server.
    let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = probe.local_addr().unwrap();
    drop(probe);

    let config = ydf::serving::ServerConfig {
        addr: addr.to_string(),
        workers: 2,
        ..Default::default()
    };
    let server = std::thread::spawn(move || ydf::serving::serve(registry, &config));

    // Wait for the listener to come up.
    let mut stream = None;
    for _ in 0..100 {
        match TcpStream::connect(addr) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    let stream = stream.expect("server came up within 2s");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    let mut rpc = |line: &str| -> Json {
        writeln!(writer, "{line}").unwrap();
        writer.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        Json::parse(resp.trim()).unwrap()
    };

    let health = rpc(r#"{"cmd": "health"}"#);
    assert_eq!(health.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(health.req_str("model_type").unwrap(), "GRADIENT_BOOSTED_TREES");
    assert_eq!(health.req_str("model").unwrap(), "alpha"); // default model
    assert_eq!(health.req_arr("models").unwrap().len(), 2);

    let spec = rpc(r#"{"cmd": "spec", "model": "beta"}"#);
    assert_eq!(spec.req_str("label").unwrap(), "income");
    assert_eq!(spec.req_str("model").unwrap(), "beta");
    assert_eq!(spec.req_arr("features").unwrap().len(), 8);

    // Un-routed requests go to the default model.
    let single = rpc(r#"{"age": 44, "education": "Masters"}"#);
    assert_eq!(single.req_str("model").unwrap(), "alpha");
    let preds = single.req_arr("predictions").unwrap();
    assert_eq!(preds.len(), 1);
    let p0 = preds[0].as_arr().unwrap();
    assert_eq!(p0.len(), 2);
    let total: f64 = p0.iter().map(|v| v.as_f64().unwrap()).sum();
    assert!((total - 1.0).abs() < 1e-9);

    // Routed requests hit the named model (the two models disagree).
    let via_a = rpc(r#"{"model": "alpha", "rows": [{"age": 44, "education": "Masters"}]}"#);
    let via_b = rpc(r#"{"model": "beta", "rows": [{"age": 44, "education": "Masters"}]}"#);
    assert_eq!(via_b.req_str("model").unwrap(), "beta");
    assert_eq!(
        via_a.req_arr("predictions").unwrap().len(),
        via_b.req_arr("predictions").unwrap().len()
    );
    assert_eq!(via_a.req_arr("predictions").unwrap()[0], single.req_arr("predictions").unwrap()[0]);

    let multi = rpc(r#"{"rows": [{"age": 23}, {"age": 67, "workclass": "Private"}, {}]}"#);
    assert_eq!(multi.req_arr("predictions").unwrap().len(), 3);

    // Unknown model: clean in-band error — and the connection survives
    // (the very next request on the same socket is answered).
    let unknown_model = rpc(r#"{"model": "gamma", "rows": [{"age": 30}]}"#);
    let err = unknown_model.req_str("error").unwrap();
    assert!(err.contains("gamma") && err.contains("alpha"), "{err}");
    let after = rpc(r#"{"age": 30}"#);
    assert_eq!(after.req_arr("predictions").unwrap().len(), 1);

    let bad = rpc("this is not json");
    assert!(bad.req_str("error").unwrap().contains("invalid JSON"), "{bad}");
    let unknown = rpc(r#"{"rows": [{"flux_capacitance": 1.21}]}"#);
    assert!(unknown.req_str("error").unwrap().contains("flux_capacitance"), "{unknown}");

    // Per-model stats: aggregate at the top level, breakdown under
    // "models".
    let stats = rpc(r#"{"cmd": "stats"}"#);
    assert!(stats.req_f64("requests").unwrap() >= 5.0);
    assert!(stats.req_f64("errors").unwrap() >= 3.0);
    let models = stats.req("models").unwrap();
    assert!(models.req("alpha").unwrap().req_f64("requests").unwrap() >= 4.0);
    assert_eq!(models.req("beta").unwrap().req_f64("requests").unwrap(), 1.0);
    assert_eq!(models.req("beta").unwrap().req_f64("errors").unwrap(), 0.0);

    // An idle connection that never sends anything must not stall
    // shutdown: the server closes registered connections on exit.
    let idle = TcpStream::connect(addr).expect("idle connection accepted");

    let bye = rpc(r#"{"cmd": "shutdown"}"#);
    assert_eq!(bye.get("ok"), Some(&Json::Bool(true)));
    server.join().unwrap().expect("server exits cleanly");
    drop(idle);
}

/// Hot-swap isolation: while one model is swapped repeatedly under
/// concurrent load, a neighboring model's predictions stay bit-identical
/// to its offline `predict_block`, every request accepted by a draining
/// generation is still answered (zero drops), and clients of the swapped
/// name converge to the new generation.
#[test]
fn untouched_model_bit_identical_while_neighbor_swaps() {
    let rows = adult_json_rows(64);
    let registry = Arc::new(Registry::new(BatcherConfig {
        max_delay: Duration::from_micros(200),
        ..Default::default()
    }));
    registry.register("keep", common::adult_session_owned(300, 71, 6, 4)).unwrap();
    registry.register("churn", common::adult_session_owned(300, 72, 4, 3)).unwrap();

    // Offline reference through the exact session behind "keep".
    let keep = registry.resolve(Some("keep")).unwrap();
    let reference = {
        let mut block = decode_all(keep.session(), &rows);
        keep.session().predict_block(&mut block)
    };
    let dim = keep.session().output_dim();

    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    std::thread::scope(|scope| {
        // 3 clients hammering "keep": bit-identity on every response.
        for client in 0..3usize {
            let registry = Arc::clone(&registry);
            let (rows, reference, stop) = (&rows, &reference, Arc::clone(&stop));
            scope.spawn(move || {
                let mut req = 0usize;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let start = (client * 13 + req * 7) % (rows.len() - 8);
                    let entry = registry.resolve(Some("keep")).unwrap();
                    let block = decode_all(entry.session(), &rows[start..start + 8]);
                    let out = entry.batcher().submit(&block).unwrap().wait().unwrap();
                    assert_eq!(
                        out.as_slice(),
                        &reference[start * dim..(start + 8) * dim],
                        "'keep' drifted during a neighbor swap (client {client} req {req})"
                    );
                    req += 1;
                }
            });
        }
        // 2 clients hammering "churn": every *accepted* request must be
        // answered even when its generation is mid-drain; a submit that
        // loses the race to the swap sees a clean Shutdown rejection and
        // re-resolves.
        for client in 0..2usize {
            let registry = Arc::clone(&registry);
            let (rows, stop) = (&rows, Arc::clone(&stop));
            scope.spawn(move || {
                let mut req = 0usize;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let start = (client * 11 + req * 5) % (rows.len() - 4);
                    let entry = registry.resolve(Some("churn")).unwrap();
                    let block = decode_all(entry.session(), &rows[start..start + 4]);
                    match entry.batcher().submit(&block) {
                        Ok(pending) => {
                            let out = pending.wait().expect("accepted requests are never dropped");
                            assert_eq!(out.len(), 4 * entry.session().output_dim());
                            req += 1;
                        }
                        Err(SubmitError::Shutdown) => continue, // swapped out: re-resolve
                        Err(e) => panic!("unexpected rejection: {e}"),
                    }
                }
            });
        }
        // Main thread: swap "churn" three times mid-traffic.
        let mut last_generation = 0;
        for round in 0..3u64 {
            std::thread::sleep(Duration::from_millis(30));
            let incoming = common::adult_session_owned(300, 80 + round, 3 + round as usize, 3);
            let generation = registry.swap("churn", incoming).unwrap();
            assert!(generation > last_generation);
            last_generation = generation;
        }
        std::thread::sleep(Duration::from_millis(30));
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
    });

    // The surviving registry routes to the last generation, still Serving.
    let churn = registry.resolve(Some("churn")).unwrap();
    assert_eq!(churn.state(), ydf::serving::Lifecycle::Serving);
    // Old generations drained out; the health log kept their trail.
    let log = registry.transitions_json().to_string();
    assert!(log.contains("Serving"), "{log}");
    assert_eq!(registry.stats_json().req_f64("reloads").unwrap(), 3.0);
}

/// The request-line length cap: a peer streaming more than
/// `max_line_bytes` without a newline gets one in-band error naming the
/// cap, the connection is closed (not the server), `overlong_lines`
/// shows up in stats, and a fresh connection still serves.
#[test]
fn overlong_request_line_answered_in_band_and_connection_closed() {
    use std::io::{BufRead, BufReader, Read, Write};
    use std::net::TcpStream;

    let registry = Registry::new(BatcherConfig {
        max_delay: Duration::ZERO,
        ..Default::default()
    });
    registry.register("capped", common::adult_session_owned(200, 61, 3, 3)).unwrap();

    let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = probe.local_addr().unwrap();
    drop(probe);
    let config = ydf::serving::ServerConfig {
        addr: addr.to_string(),
        workers: 2,
        max_line_bytes: 4096,
        ..Default::default()
    };
    let server = std::thread::spawn(move || ydf::serving::serve(registry, &config));
    let mut stream = None;
    for _ in 0..100 {
        match TcpStream::connect(addr) {
            Ok(s) => {
                stream = Some(s);
                break;
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
    let stream = stream.expect("server came up within 2s");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;

    // One byte past the cap, no newline: the server must answer in-band
    // the moment the budget is exhausted — not wait for a line that
    // never ends, not buffer beyond the cap. (Exactly cap + 1 bytes so
    // the server consumes everything sent: closing with unread bytes in
    // the socket would turn the close into a reply-destroying RST and
    // make the test racy.)
    let flood = vec![b'x'; 4096 + 1];
    writer.write_all(&flood).unwrap();
    writer.flush().unwrap();
    let mut resp = String::new();
    reader.read_line(&mut resp).unwrap();
    let err = Json::parse(resp.trim()).unwrap();
    let msg = err.req_str("error").unwrap();
    assert!(msg.contains("max_line_bytes") && msg.contains("4096"), "{msg}");
    // The connection is closed after the reply: the next read sees EOF.
    let mut rest = Vec::new();
    assert_eq!(reader.read_to_end(&mut rest).unwrap(), 0, "connection must be closed");

    // A fresh connection serves normally and the counter recorded the
    // event — in stats and in the Prometheus exposition.
    let fresh = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(fresh.try_clone().unwrap());
    let mut writer = fresh;
    let mut rpc = |line: &str| -> Json {
        writeln!(writer, "{line}").unwrap();
        writer.flush().unwrap();
        let mut resp = String::new();
        reader.read_line(&mut resp).unwrap();
        Json::parse(resp.trim()).unwrap()
    };
    let ok = rpc(r#"{"age": 33}"#);
    assert_eq!(ok.req_arr("predictions").unwrap().len(), 1);
    let stats = rpc(r#"{"cmd": "stats"}"#);
    assert_eq!(stats.req_f64("overlong_lines").unwrap(), 1.0, "{stats}");
    let metrics = rpc(r#"{"cmd": "metrics"}"#);
    assert!(
        metrics.req_str("metrics").unwrap().contains("ydf_serving_overlong_lines_total"),
        "exposition must carry the overlong-lines family"
    );

    // A line of exactly the cap (content + newline) is *not* overlong.
    let mut exact = format!(r#"{{"age": 41, "pad": "{}"#, "y".repeat(3000));
    exact.push_str("\"}");
    assert!(exact.len() <= 4096);
    let resp = rpc(&exact);
    assert!(
        resp.req_str("error").unwrap().contains("pad"),
        "under-cap line reaches JSON handling (unknown feature): {resp}"
    );

    let bye = rpc(r#"{"cmd": "shutdown"}"#);
    assert_eq!(bye.get("ok"), Some(&Json::Bool(true)));
    server.join().unwrap().expect("server exits cleanly");
}
