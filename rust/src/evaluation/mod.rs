//! Model evaluation (§2.2, §3.6): metrics with confidence intervals, the
//! Appendix B.3 evaluation report, cross-validation and pairwise model
//! comparison with statistical tests.
//!
//! The entry point is [`evaluate_model`], which batch-predicts the
//! dataset through the fastest compiled engine
//! ([`crate::inference::predict_flat`]) and returns an [`Evaluation`]:
//! accuracy with bootstrap and Wilson intervals, log loss, confusion
//! matrix and per-class one-vs-rest AUC/PR-AUC for classification, RMSE
//! for regression; `Evaluation::report()` renders the Appendix B.3 text
//! report. [`cv`] adds k-fold cross-validation and [`comparison`] the
//! pairwise statistical tests of §5.

pub mod comparison;
pub mod cv;
pub mod metrics;

use crate::dataset::Dataset;
use crate::inference::predict_flat;
use crate::model::{Model, Task};
use crate::utils::rng::Rng;
use crate::utils::stats;

/// Per-class one-vs-rest metrics (Appendix B.3 "One vs other classes").
#[derive(Clone, Debug)]
pub struct OneVsRest {
    pub class_name: String,
    pub auc: f64,
    /// Hanley–McNeil closed-form CI `[H]`.
    pub auc_ci_h: (f64, f64),
    /// Bootstrap CI `[B]`.
    pub auc_ci_b: (f64, f64),
    pub pr_auc: f64,
    pub pr_auc_ci_b: (f64, f64),
    pub average_precision: f64,
}

/// A full classification/regression evaluation.
#[derive(Clone, Debug)]
pub struct Evaluation {
    pub task: Task,
    pub label: String,
    pub num_examples: usize,
    pub accuracy: f64,
    /// Bootstrap CI of the accuracy (`CI95[W]` in the report; we use the
    /// percentile bootstrap and additionally report the Wilson interval).
    pub accuracy_ci_b: (f64, f64),
    pub accuracy_ci_wilson: (f64, f64),
    pub log_loss: f64,
    pub error_rate: f64,
    /// Accuracy/logloss of always predicting the majority class.
    pub default_accuracy: f64,
    pub default_log_loss: f64,
    /// `confusion[truth][predicted]`.
    pub confusion: Vec<Vec<u64>>,
    pub class_names: Vec<String>,
    pub one_vs_rest: Vec<OneVsRest>,
    /// RMSE for regression evaluations.
    pub rmse: f64,
}

/// Evaluates a model on a dataset (held-out examples). `label` must match
/// the model's label column name.
pub fn evaluate_model(
    model: &dyn Model,
    ds: &Dataset,
    label: &str,
) -> Result<Evaluation, String> {
    let label_col = ds.column_index(label).ok_or_else(|| {
        format!("evaluation dataset has no column \"{label}\" (the model's label).")
    })?;
    match model.task() {
        Task::Classification => evaluate_classification(model, ds, label, label_col),
        Task::Regression => evaluate_regression(model, ds, label, label_col),
    }
}

fn evaluate_classification(
    model: &dyn Model,
    ds: &Dataset,
    label: &str,
    label_col: usize,
) -> Result<Evaluation, String> {
    let labels = ds.columns[label_col]
        .as_categorical()
        .ok_or_else(|| format!("label column \"{label}\" is not categorical."))?;
    let n = ds.num_rows();
    if n == 0 {
        return Err("cannot evaluate on an empty dataset.".to_string());
    }
    // Batch path: fastest compatible engine, flat row-major output — the
    // evaluation layer never materializes per-row prediction Vecs.
    let (probs, dim) = predict_flat(model, ds);
    let num_classes = model.num_classes();
    debug_assert_eq!(dim, num_classes);
    let class_names = model.class_names();

    let mut confusion = vec![vec![0u64; num_classes]; num_classes];
    let mut correct_flags = Vec::with_capacity(n);
    for (r, &y) in labels.iter().enumerate() {
        let pred = crate::model::argmax(&probs[r * dim..(r + 1) * dim]);
        confusion[y as usize][pred] += 1;
        correct_flags.push((pred as u32 == y) as u8 as f64);
    }
    let accuracy = metrics::accuracy_flat(&probs, dim, labels);
    let log_loss = metrics::log_loss_flat(&probs, dim, labels);

    // Majority-class baseline ("Default" rows of B.3).
    let mut class_counts = vec![0u64; num_classes];
    for &y in labels {
        class_counts[y as usize] += 1;
    }
    let majority = class_counts.iter().copied().max().unwrap_or(0);
    let default_accuracy = majority as f64 / n as f64;
    let default_log_loss = -class_counts
        .iter()
        .map(|&c| {
            let p = c as f64 / n as f64;
            if c > 0 {
                p * p.max(1e-12).ln()
            } else {
                0.0
            }
        })
        .sum::<f64>();

    let mut rng = Rng::seed_from_u64(0xE7A1);
    let accuracy_ci_b = stats::bootstrap_ci(&correct_flags, stats::mean, 500, 0.05, &mut rng);
    let correct_count = correct_flags.iter().filter(|&&f| f > 0.5).count() as u64;
    let accuracy_ci_wilson = stats::wilson_interval(correct_count, n as u64, 1.96);

    // One-vs-rest per class.
    let mut one_vs_rest = Vec::new();
    for k in 0..num_classes {
        let scores: Vec<f64> = (0..n).map(|r| probs[r * dim + k]).collect();
        let positives: Vec<bool> = labels.iter().map(|&y| y as usize == k).collect();
        let n_pos = positives.iter().filter(|&&p| p).count();
        let auc = metrics::roc_auc(&scores, &positives);
        one_vs_rest.push(OneVsRest {
            class_name: class_names.get(k).cloned().unwrap_or_else(|| format!("c{k}")),
            auc,
            auc_ci_h: metrics::auc_hanley_ci(auc, n_pos, n - n_pos, 1.96),
            auc_ci_b: metrics::auc_bootstrap_ci(&scores, &positives, 100, 0.05, &mut rng),
            pr_auc: metrics::average_precision(&scores, &positives),
            pr_auc_ci_b: {
                // Bootstrap of AP.
                let mut vals = Vec::with_capacity(100);
                let mut s = vec![0.0; n];
                let mut p = vec![false; n];
                for _ in 0..100 {
                    for i in 0..n {
                        let j = rng.uniform_usize(n);
                        s[i] = scores[j];
                        p[i] = positives[j];
                    }
                    vals.push(metrics::average_precision(&s, &p));
                }
                vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
                (
                    stats::quantile_sorted(&vals, 0.025),
                    stats::quantile_sorted(&vals, 0.975),
                )
            },
            average_precision: metrics::average_precision(&scores, &positives),
        });
    }

    Ok(Evaluation {
        task: Task::Classification,
        label: label.to_string(),
        num_examples: n,
        accuracy,
        accuracy_ci_b,
        accuracy_ci_wilson,
        log_loss,
        error_rate: 1.0 - accuracy,
        default_accuracy,
        default_log_loss,
        confusion,
        class_names,
        one_vs_rest,
        rmse: 0.0,
    })
}

fn evaluate_regression(
    model: &dyn Model,
    ds: &Dataset,
    label: &str,
    label_col: usize,
) -> Result<Evaluation, String> {
    let targets = ds.columns[label_col]
        .as_numerical()
        .ok_or_else(|| format!("label column \"{label}\" is not numerical."))?;
    let n = ds.num_rows();
    // Batch path (dim = 1 for regression models).
    let (preds, _dim) = predict_flat(model, ds);
    Ok(Evaluation {
        task: Task::Regression,
        label: label.to_string(),
        num_examples: n,
        accuracy: 0.0,
        accuracy_ci_b: (0.0, 0.0),
        accuracy_ci_wilson: (0.0, 0.0),
        log_loss: 0.0,
        error_rate: 0.0,
        default_accuracy: 0.0,
        default_log_loss: 0.0,
        confusion: vec![],
        class_names: vec![],
        one_vs_rest: vec![],
        rmse: metrics::rmse(&preds, targets),
    })
}

impl Evaluation {
    /// Renders the Appendix B.3 evaluation report.
    pub fn report(&self) -> String {
        match self.task {
            Task::Regression => format!(
                "Evaluation:\nNumber of predictions: {}\nTask: REGRESSION\nLabel: {}\n\nRMSE: \
                 {:.6}\n",
                self.num_examples, self.label, self.rmse
            ),
            Task::Classification => {
                let mut out = format!(
                    "Evaluation:\nNumber of predictions (without weights): {}\nNumber of \
                     predictions (with weights): {}\nTask: CLASSIFICATION\nLabel: {}\n\n\
                     Accuracy: {:.6} CI95[B][{:.6} {:.6}] CI95[Wilson][{:.6} {:.6}]\n\
                     LogLoss: {:.6}\nErrorRate: {:.6}\n\nDefault Accuracy: {:.6}\nDefault \
                     LogLoss: {:.6}\n\nConfusion Table: truth\\prediction\n",
                    self.num_examples,
                    self.num_examples,
                    self.label,
                    self.accuracy,
                    self.accuracy_ci_b.0,
                    self.accuracy_ci_b.1,
                    self.accuracy_ci_wilson.0,
                    self.accuracy_ci_wilson.1,
                    self.log_loss,
                    self.error_rate,
                    self.default_accuracy,
                    self.default_log_loss,
                );
                // Confusion table.
                out.push_str(&format!(
                    "        {}\n",
                    self.class_names
                        .iter()
                        .map(|c| format!("{c:>10}"))
                        .collect::<Vec<_>>()
                        .join(" ")
                ));
                for (t, row) in self.confusion.iter().enumerate() {
                    out.push_str(&format!(
                        "{:>7} {}\n",
                        self.class_names[t],
                        row.iter().map(|c| format!("{c:>10}")).collect::<Vec<_>>().join(" ")
                    ));
                }
                out.push_str(&format!("Total: {}\n\nOne vs other classes:\n", self.num_examples));
                for ovr in &self.one_vs_rest {
                    out.push_str(&format!(
                        "  \"{}\" vs. the others\n    auc: {:.6} CI95[H][{:.5} {:.5}] \
                         CI95[B][{:.5} {:.5}]\n    p/r-auc: {:.5} CI95[B][{:.5} {:.5}]\n    \
                         ap: {:.6}\n",
                        ovr.class_name,
                        ovr.auc,
                        ovr.auc_ci_h.0,
                        ovr.auc_ci_h.1,
                        ovr.auc_ci_b.0,
                        ovr.auc_ci_b.1,
                        ovr.pr_auc,
                        ovr.pr_auc_ci_b.0,
                        ovr.pr_auc_ci_b.1,
                        ovr.average_precision,
                    ));
                }
                out
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::learner::{GradientBoostedTreesLearner, Learner};

    fn trained() -> (Box<dyn Model>, Dataset, Dataset) {
        let train = synthetic::adult_like(500, 61);
        let test = synthetic::adult_like(300, 62);
        let mut cfg = crate::learner::gbt::GbtConfig::new("income");
        cfg.num_trees = 20;
        cfg.max_depth = 4;
        let model = GradientBoostedTreesLearner::new(cfg).train(&train).unwrap();
        (model, train, test)
    }

    #[test]
    fn evaluation_on_heldout() {
        let (model, _, test) = trained();
        let ev = evaluate_model(model.as_ref(), &test, "income").unwrap();
        assert!(ev.accuracy > 0.7, "accuracy {}", ev.accuracy);
        assert!(ev.accuracy > ev.default_accuracy);
        assert!(ev.log_loss < ev.default_log_loss);
        assert!(ev.accuracy_ci_b.0 <= ev.accuracy && ev.accuracy <= ev.accuracy_ci_b.1);
        // Confusion matrix sums to n.
        let total: u64 = ev.confusion.iter().flatten().sum();
        assert_eq!(total as usize, ev.num_examples);
        // AUC above chance for both one-vs-rest views.
        for ovr in &ev.one_vs_rest {
            assert!(ovr.auc > 0.6, "{} auc {}", ovr.class_name, ovr.auc);
            assert!(ovr.auc_ci_h.0 <= ovr.auc && ovr.auc <= ovr.auc_ci_h.1);
        }
    }

    #[test]
    fn report_has_b3_sections() {
        let (model, _, test) = trained();
        let ev = evaluate_model(model.as_ref(), &test, "income").unwrap();
        let rep = ev.report();
        for needle in [
            "Task: CLASSIFICATION",
            "Accuracy:",
            "CI95[B]",
            "LogLoss:",
            "Default Accuracy:",
            "Confusion Table: truth\\prediction",
            "One vs other classes:",
            "vs. the others",
        ] {
            assert!(rep.contains(needle), "missing {needle}\n{rep}");
        }
    }

    #[test]
    fn missing_label_column_actionable() {
        let (model, _, test) = trained();
        let err = match evaluate_model(model.as_ref(), &test, "nope") {
            Err(e) => e,
            Ok(_) => panic!(),
        };
        assert!(err.contains("no column"), "{err}");
    }
}
