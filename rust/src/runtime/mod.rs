//! PJRT runtime: loads AOT-compiled XLA computations (HLO *text* emitted by
//! `python/compile/aot.py`) and executes them on the CPU PJRT client.
//!
//! HLO text — not a serialized `HloModuleProto` — is the interchange
//! format: jax ≥ 0.5 emits protos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! The external `xla` crate is not vendored offline, so the module has two
//! builds selected by the `xla` cargo feature: the real PJRT binding, and
//! a stub whose [`Runtime::cpu`] returns an actionable error while the
//! shape-checked [`Literal`] helpers keep working (they are pure Rust).
//! Engine-selection code treats both uniformly: the PJRT engine is simply
//! "unavailable" when the feature is off or the artifact is absent.

use std::path::Path;

/// Default artifact directory (overridable with YDF_ARTIFACTS).
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("YDF_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

#[cfg(feature = "xla")]
mod imp {
    use super::*;

    /// A PJRT client plus compiled-executable cache.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    /// A compiled XLA executable.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        pub path: String,
    }

    /// A device-transferable literal (re-export of the binding's type).
    pub type Literal = xla::Literal;

    impl Runtime {
        /// Creates a CPU PJRT client.
        pub fn cpu() -> Result<Runtime, String> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| format!("cannot create PJRT CPU client: {e}"))?;
            Ok(Runtime { client })
        }

        pub fn platform_name(&self) -> String {
            self.client.platform_name()
        }

        /// Loads and compiles an HLO text artifact.
        pub fn load_hlo_text(&self, path: &Path) -> Result<Executable, String> {
            let proto = xla::HloModuleProto::from_text_file(path).map_err(|e| {
                format!(
                    "cannot parse HLO text {}: {e}. Re-generate artifacts with `make artifacts`.",
                    path.display()
                )
            })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| format!("XLA compilation of {} failed: {e}", path.display()))?;
            Ok(Executable { exe, path: path.display().to_string() })
        }
    }

    impl Executable {
        /// Executes with literal inputs; returns the elements of the output
        /// tuple (aot.py lowers with `return_tuple=True`).
        pub fn run(&self, inputs: &[Literal]) -> Result<Vec<Literal>, String> {
            let result = self
                .exe
                .execute::<Literal>(inputs)
                .map_err(|e| format!("execution of {} failed: {e}", self.path))?;
            let mut out = result[0][0]
                .to_literal_sync()
                .map_err(|e| format!("cannot fetch output of {}: {e}", self.path))?;
            // Tuples report their arity through decompose; plain outputs pass
            // through unchanged.
            match out.decompose_tuple() {
                Ok(parts) if !parts.is_empty() => Ok(parts),
                _ => Ok(vec![out]),
            }
        }
    }

    /// Builds an f32 literal of the given shape from a flat slice.
    pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal, String> {
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| format!("cannot reshape f32 literal to {dims:?}: {e}"))
    }

    /// Builds an i32 literal of the given shape from a flat slice.
    pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<Literal, String> {
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| format!("cannot reshape i32 literal to {dims:?}: {e}"))
    }

    /// Extracts an f32 vector from a literal.
    pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>, String> {
        lit.to_vec::<f32>().map_err(|e| format!("cannot read f32 output: {e}"))
    }
}

#[cfg(not(feature = "xla"))]
mod imp {
    use super::*;

    const UNAVAILABLE: &str = "the PJRT/XLA runtime is not built into this binary (the `xla` \
                               crate is not vendored offline). Rebuild with `--features xla` \
                               on a machine with the dependency available.";

    /// Stub runtime: construction always fails with an actionable message.
    pub struct Runtime {
        _private: (),
    }

    /// Stub executable (never constructed; `load_hlo_text` cannot succeed).
    pub struct Executable {
        pub path: String,
    }

    /// Shape-checked host literal: the subset of the binding's `Literal`
    /// that pure-Rust callers (and the unit tests) rely on.
    pub enum Literal {
        F32(Vec<f32>),
        I32(Vec<i32>),
    }

    impl Literal {
        pub fn element_count(&self) -> usize {
            match self {
                Literal::F32(v) => v.len(),
                Literal::I32(v) => v.len(),
            }
        }
    }

    impl Runtime {
        pub fn cpu() -> Result<Runtime, String> {
            Err(UNAVAILABLE.to_string())
        }

        pub fn platform_name(&self) -> String {
            "unavailable".to_string()
        }

        pub fn load_hlo_text(&self, _path: &Path) -> Result<Executable, String> {
            Err(UNAVAILABLE.to_string())
        }
    }

    impl Executable {
        pub fn run(&self, _inputs: &[Literal]) -> Result<Vec<Literal>, String> {
            Err(UNAVAILABLE.to_string())
        }
    }

    fn check_dims(len: usize, dims: &[i64]) -> Result<(), String> {
        let expect: i64 = dims.iter().product();
        if expect < 0 || len != expect as usize {
            return Err(format!("cannot reshape literal of {len} elements to {dims:?}"));
        }
        Ok(())
    }

    pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal, String> {
        check_dims(data.len(), dims)?;
        Ok(Literal::F32(data.to_vec()))
    }

    pub fn literal_i32(data: &[i32], dims: &[i64]) -> Result<Literal, String> {
        check_dims(data.len(), dims)?;
        Ok(Literal::I32(data.to_vec()))
    }

    pub fn to_vec_f32(lit: &Literal) -> Result<Vec<f32>, String> {
        match lit {
            Literal::F32(v) => Ok(v.clone()),
            Literal::I32(_) => Err("cannot read f32 output: literal is i32".to_string()),
        }
    }
}

pub use imp::{literal_f32, literal_i32, to_vec_f32, Executable, Literal, Runtime};

#[cfg(test)]
mod tests {
    use super::*;

    // The runtime tests require built artifacts; they are exercised by
    // rust/tests/pjrt_roundtrip.rs (integration) so unit tests here only
    // cover literal helpers.

    #[test]
    fn literal_helpers_roundtrip() {
        let lit = literal_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_vec_f32(&lit).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let lit = literal_i32(&[1, 2, 3], &[3]).unwrap();
        assert_eq!(lit.element_count(), 3);
    }

    #[test]
    fn literal_shape_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0, 3.0], &[2, 2]).is_err());
    }

    #[test]
    fn artifacts_dir_env_override() {
        std::env::set_var("YDF_ARTIFACTS", "/tmp/ydf_artifacts_test");
        assert_eq!(
            artifacts_dir(),
            std::path::PathBuf::from("/tmp/ydf_artifacts_test")
        );
        std::env::remove_var("YDF_ARTIFACTS");
    }
}
