# Convenience targets. The crate itself is plain cargo; see README.md.

.PHONY: build test docs bench serve-smoke verify artifacts

build:
	cargo build --release

test:
	cargo test -q

# Documentation gate: rustdoc must be warning-free and every doctest must
# pass. Part of the tier-1 verify recipe (.claude/skills/verify/SKILL.md).
docs:
	RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
	cargo test --doc

bench:
	cargo bench --bench b4_engines
	cargo bench --bench b5_serving
	cargo bench --bench b6_training

# End-to-end serving smoke: ephemeral-port server, JSON requests
# (single-row, multi-row, malformed), protocol shutdown. Depends on
# `build` so the release binary exists even under `make -j`.
serve-smoke: build
	bash scripts/serve_smoke.sh

# Tier-1 gate (ROADMAP.md) plus the docs and serving gates.
verify: build test docs serve-smoke

# Build-time JAX/Pallas artifacts for the PJRT/XLA engine (requires the
# python/ toolchain; the Rust side is feature-gated behind `--features xla`).
artifacts:
	python3 python/compile/aot.py
