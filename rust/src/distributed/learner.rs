//! Distributed GBT learner: the paper's feature-parallel exact training
//! (§3.9) packaged as a LEARNER, so it is interchangeable with the
//! in-memory [`GradientBoostedTreesLearner`] — same inputs, same model
//! type, and (by the exactness of the algorithm) the *same model*.

use super::backend::Backend;
use super::{grow_tree_distributed, shard_features, NetworkStats, WorkerState};
use crate::dataset::Dataset;
use crate::learner::gbt::GbtConfig;
use crate::learner::{classification_labels, feature_columns, Learner};
use crate::model::forest::{GbtLoss, GradientBoostedTreesModel};
use crate::model::{Model, Task};
use crate::splitter::score::Labels;
use crate::splitter::{ColumnIndex, NodeScratch};
use crate::utils::rng::Rng;
use crate::utils::stats::sigmoid;

/// Feature-parallel distributed GBT (binary classification).
pub struct DistributedGbtLearner<B: Backend> {
    pub config: GbtConfig,
    pub num_workers: usize,
    pub backend: B,
    /// Network IO accounting, readable after training.
    pub net: NetworkStats,
}

impl<B: Backend> DistributedGbtLearner<B> {
    pub fn new(config: GbtConfig, num_workers: usize, backend: B) -> Self {
        DistributedGbtLearner { config, num_workers, backend, net: NetworkStats::default() }
    }
}

impl<B: Backend> Learner for DistributedGbtLearner<B> {
    fn name(&self) -> &'static str {
        "DISTRIBUTED_GRADIENT_BOOSTED_TREES"
    }

    fn label(&self) -> &str {
        &self.config.label
    }

    fn train_with_valid(
        &self,
        ds: &Dataset,
        _valid: Option<&Dataset>,
    ) -> Result<Box<dyn Model>, String> {
        let cfg = &self.config;
        if cfg.task != Task::Classification {
            return Err("the distributed GBT learner supports classification only.".to_string());
        }
        let (label_col, labels) = classification_labels(ds, &cfg.label)?;
        crate::learner::require_binary(ds, label_col)?;
        let n = ds.num_rows();
        let features = feature_columns(ds, label_col);
        let shards = shard_features(&features, self.num_workers);
        // Shared read-only column index (the paper's workers each hold
        // their shard's sort orders; here the lazily built index only ever
        // materializes the columns a worker actually touches).
        let index = ColumnIndex::new(ds);
        let mut workers: Vec<WorkerState> = shards
            .into_iter()
            .map(|features| WorkerState {
                features,
                scratch: NodeScratch::new(ds.num_rows()),
                rng: Rng::seed_from_u64(cfg.seed ^ 0xD157),
            })
            .collect();

        let pos = labels.iter().filter(|&&l| l == 1).count() as f64;
        let p0 = (pos / n as f64).clamp(1e-6, 1.0 - 1e-6);
        let initial = (p0 / (1.0 - p0)).ln();
        let mut scores = vec![initial; n];
        let mut grad = vec![0.0f32; n];
        let mut hess = vec![0.0f32; n];
        let mut trees = Vec::with_capacity(cfg.num_trees);

        for _iter in 0..cfg.num_trees {
            for i in 0..n {
                let p = sigmoid(scores[i]);
                grad[i] = (p - labels[i] as f64) as f32;
                hess[i] = (p * (1.0 - p)).max(1e-6) as f32;
            }
            let labels_view = Labels::Gradients {
                grad: &grad,
                hess: &hess,
                use_hessian_gain: cfg.use_hessian_gain,
                l1: cfg.l1,
                l2: cfg.l2,
            };
            let mut tree = grow_tree_distributed(
                ds,
                (0..n as u32).collect(),
                &labels_view,
                &mut workers,
                &index,
                &cfg.splitter,
                cfg.max_depth,
                cfg.min_examples,
                &self.backend,
                &self.net,
            );
            for node in &mut tree.nodes {
                if node.is_leaf() {
                    node.value[0] *= cfg.shrinkage as f32;
                }
            }
            for (i, s) in scores.iter_mut().enumerate() {
                *s += tree.eval_ds(ds, i).value[0] as f64;
            }
            trees.push(tree);
        }

        Ok(Box::new(GradientBoostedTreesModel {
            spec: ds.spec.clone(),
            label_col,
            task: Task::Classification,
            loss: GbtLoss::BinomialLogLikelihood,
            trees,
            trees_per_iter: 1,
            initial_predictions: vec![initial],
            validation_loss: None,
            self_eval: None,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::distributed::backend::{InProcessBackend, ThreadBackend};
    use crate::evaluation_free_accuracy;
    use crate::learner::decision_tree::GrowingStrategy;
    use crate::learner::GradientBoostedTreesLearner;

    fn cfg() -> GbtConfig {
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 5;
        cfg.max_depth = 4;
        cfg.validation_ratio = 0.0;
        cfg.early_stopping = crate::learner::gbt::EarlyStopping::None;
        cfg.growing = GrowingStrategy::Local;
        cfg
    }

    #[test]
    fn distributed_equals_single_machine() {
        // Exact distributed training (Guillame-Bert & Teytaud): the
        // distributed learner must produce the same model as the
        // single-machine learner.
        let ds = synthetic::adult_like(300, 151);
        let single = GradientBoostedTreesLearner::new(cfg()).train(&ds).unwrap();
        let dist2 =
            DistributedGbtLearner::new(cfg(), 2, InProcessBackend).train(&ds).unwrap();
        let dist4 =
            DistributedGbtLearner::new(cfg(), 4, InProcessBackend).train(&ds).unwrap();
        assert_eq!(single.to_json().to_string(), dist2.to_json().to_string());
        assert_eq!(dist2.to_json().to_string(), dist4.to_json().to_string());
    }

    #[test]
    fn thread_backend_equals_in_process() {
        let ds = synthetic::adult_like(200, 153);
        let a = DistributedGbtLearner::new(cfg(), 3, InProcessBackend).train(&ds).unwrap();
        let b = DistributedGbtLearner::new(cfg(), 3, ThreadBackend).train(&ds).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }

    #[test]
    fn network_io_recorded() {
        let ds = synthetic::adult_like(150, 155);
        let learner = DistributedGbtLearner::new(cfg(), 4, InProcessBackend);
        let model = learner.train(&ds).unwrap();
        assert!(evaluation_free_accuracy(model.as_ref(), &ds) > 0.7);
        assert!(learner.net.bytes_sent.load(std::sync::atomic::Ordering::Relaxed) > 0);
        assert!(learner.net.messages.load(std::sync::atomic::Ordering::Relaxed) > 0);
    }
}
