//! Flat engine: all trees compiled into contiguous structure-of-arrays
//! node tables. Removes pointer chasing and per-node heap indirection —
//! the generic fast path for any forest model (§3.7).

use super::InferenceEngine;
use crate::dataset::{AttrValue, ColumnData, Dataset, Observation};
use crate::model::forest::{GbtLoss, GradientBoostedTreesModel, RandomForestModel};
use crate::model::tree::{bitmap_contains, Condition, DecisionTree};
use crate::model::{Model, Task};

const KIND_LEAF: u8 = 0;
const KIND_HIGHER: u8 = 1;
const KIND_CONTAINS: u8 = 2;
const KIND_CONTAINS_SET: u8 = 3;
const KIND_OBLIQUE: u8 = 4;
const KIND_IS_TRUE: u8 = 5;

/// One flattened node. Children are stored adjacently: positive child at
/// `child`, negative child at `child + 1`.
#[derive(Clone, Copy)]
struct FlatNode {
    kind: u8,
    missing_to_positive: bool,
    attr: u32,
    threshold: f32,
    /// Offset+len into `bitmaps` (contains) or `oblique` (oblique terms),
    /// or offset into `leaf_values` for leaves.
    aux: u32,
    aux_len: u32,
    child: u32,
}

/// Aggregation mode, fixed at compile time.
enum Aggregate {
    RfAverage { num_classes: usize, winner_take_all: bool },
    RfRegression,
    Gbt { loss: GbtLoss, dim: usize, initial: Vec<f64> },
}

pub struct FlatEngine {
    nodes: Vec<FlatNode>,
    roots: Vec<u32>,
    bitmaps: Vec<u64>,
    /// Oblique terms: (attr, weight) pairs.
    oblique: Vec<(u32, f32)>,
    leaf_values: Vec<f32>,
    leaf_dim: usize,
    aggregate: Aggregate,
}

impl FlatEngine {
    pub fn compile(model: &dyn Model) -> Option<FlatEngine> {
        if let Some(m) = model.as_any().downcast_ref::<RandomForestModel>() {
            let num_classes = match m.task {
                Task::Classification => m.spec.columns[m.label_col].vocab_size(),
                Task::Regression => 1,
            };
            let aggregate = match m.task {
                Task::Classification => Aggregate::RfAverage {
                    num_classes,
                    winner_take_all: m.winner_take_all,
                },
                Task::Regression => Aggregate::RfRegression,
            };
            Some(Self::from_trees(&m.trees, num_classes, aggregate))
        } else if let Some(m) = model.as_any().downcast_ref::<GradientBoostedTreesModel>() {
            let aggregate = Aggregate::Gbt {
                loss: m.loss,
                dim: m.trees_per_iter,
                initial: m.initial_predictions.clone(),
            };
            Some(Self::from_trees(&m.trees, 1, aggregate))
        } else {
            None
        }
    }

    fn from_trees(trees: &[DecisionTree], leaf_dim: usize, aggregate: Aggregate) -> FlatEngine {
        let mut e = FlatEngine {
            nodes: Vec::new(),
            roots: Vec::with_capacity(trees.len()),
            bitmaps: Vec::new(),
            oblique: Vec::new(),
            leaf_values: Vec::new(),
            leaf_dim,
            aggregate,
        };
        for t in trees {
            let root = e.nodes.len() as u32;
            e.roots.push(root);
            // BFS copy with children-adjacent layout.
            // map: original index -> flat index.
            let mut flat_of = vec![u32::MAX; t.nodes.len()];
            let mut queue = std::collections::VecDeque::new();
            flat_of[0] = e.nodes.len() as u32;
            e.nodes.push(FlatNode {
                kind: KIND_LEAF,
                missing_to_positive: false,
                attr: 0,
                threshold: 0.0,
                aux: 0,
                aux_len: 0,
                child: 0,
            });
            queue.push_back(0usize);
            while let Some(orig) = queue.pop_front() {
                let node = &t.nodes[orig];
                let flat_idx = flat_of[orig] as usize;
                match &node.condition {
                    None => {
                        let aux = e.leaf_values.len() as u32;
                        e.leaf_values.extend_from_slice(&node.value);
                        // pad to leaf_dim
                        for _ in node.value.len()..leaf_dim {
                            e.leaf_values.push(0.0);
                        }
                        e.nodes[flat_idx] = FlatNode {
                            kind: KIND_LEAF,
                            missing_to_positive: false,
                            attr: 0,
                            threshold: 0.0,
                            aux,
                            aux_len: leaf_dim as u32,
                            child: 0,
                        };
                    }
                    Some(cond) => {
                        // Allocate both children adjacently.
                        let child = e.nodes.len() as u32;
                        for _ in 0..2 {
                            e.nodes.push(FlatNode {
                                kind: KIND_LEAF,
                                missing_to_positive: false,
                                attr: 0,
                                threshold: 0.0,
                                aux: 0,
                                aux_len: 0,
                                child: 0,
                            });
                        }
                        flat_of[node.positive as usize] = child;
                        flat_of[node.negative as usize] = child + 1;
                        queue.push_back(node.positive as usize);
                        queue.push_back(node.negative as usize);
                        let fl = match cond {
                            Condition::Higher { attr, threshold } => FlatNode {
                                kind: KIND_HIGHER,
                                missing_to_positive: node.missing_to_positive,
                                attr: *attr as u32,
                                threshold: *threshold,
                                aux: 0,
                                aux_len: 0,
                                child,
                            },
                            Condition::ContainsBitmap { attr, bitmap } => {
                                let aux = e.bitmaps.len() as u32;
                                e.bitmaps.extend_from_slice(bitmap);
                                FlatNode {
                                    kind: KIND_CONTAINS,
                                    missing_to_positive: node.missing_to_positive,
                                    attr: *attr as u32,
                                    threshold: 0.0,
                                    aux,
                                    aux_len: bitmap.len() as u32,
                                    child,
                                }
                            }
                            Condition::ContainsSetBitmap { attr, bitmap } => {
                                let aux = e.bitmaps.len() as u32;
                                e.bitmaps.extend_from_slice(bitmap);
                                FlatNode {
                                    kind: KIND_CONTAINS_SET,
                                    missing_to_positive: node.missing_to_positive,
                                    attr: *attr as u32,
                                    threshold: 0.0,
                                    aux,
                                    aux_len: bitmap.len() as u32,
                                    child,
                                }
                            }
                            Condition::Oblique { attrs, weights, threshold } => {
                                let aux = e.oblique.len() as u32;
                                for (&a, &w) in attrs.iter().zip(weights) {
                                    e.oblique.push((a as u32, w));
                                }
                                FlatNode {
                                    kind: KIND_OBLIQUE,
                                    missing_to_positive: node.missing_to_positive,
                                    attr: 0,
                                    threshold: *threshold,
                                    aux,
                                    aux_len: attrs.len() as u32,
                                    child,
                                }
                            }
                            Condition::IsTrue { attr } => FlatNode {
                                kind: KIND_IS_TRUE,
                                missing_to_positive: node.missing_to_positive,
                                attr: *attr as u32,
                                threshold: 0.0,
                                aux: 0,
                                aux_len: 0,
                                child,
                            },
                        };
                        e.nodes[flat_idx] = fl;
                    }
                }
            }
        }
        e
    }

    /// Evaluates one tree on a row observation; returns leaf-value offset.
    #[inline]
    fn eval_tree_row(&self, root: u32, obs: &Observation) -> u32 {
        let mut idx = root;
        loop {
            let n = &self.nodes[idx as usize];
            let go_pos = match n.kind {
                KIND_LEAF => return n.aux,
                KIND_HIGHER => match &obs[n.attr as usize] {
                    AttrValue::Num(x) if !x.is_nan() => *x >= n.threshold,
                    _ => n.missing_to_positive,
                },
                KIND_CONTAINS => match &obs[n.attr as usize] {
                    AttrValue::Cat(c) => bitmap_contains(
                        &self.bitmaps[n.aux as usize..(n.aux + n.aux_len) as usize],
                        *c,
                    ),
                    _ => n.missing_to_positive,
                },
                KIND_CONTAINS_SET => match &obs[n.attr as usize] {
                    AttrValue::CatSet(items) => {
                        let bm = &self.bitmaps[n.aux as usize..(n.aux + n.aux_len) as usize];
                        items.iter().any(|&i| bitmap_contains(bm, i))
                    }
                    _ => n.missing_to_positive,
                },
                KIND_OBLIQUE => {
                    let mut acc = 0.0f32;
                    for &(a, w) in
                        &self.oblique[n.aux as usize..(n.aux + n.aux_len) as usize]
                    {
                        if let AttrValue::Num(x) = &obs[a as usize] {
                            if !x.is_nan() {
                                acc += w * x;
                            }
                        }
                    }
                    acc >= n.threshold
                }
                KIND_IS_TRUE => match &obs[n.attr as usize] {
                    AttrValue::Bool(b) => *b,
                    _ => n.missing_to_positive,
                },
                _ => unreachable!(),
            };
            idx = if go_pos { n.child } else { n.child + 1 };
        }
    }

    /// Same traversal against column storage (batch path).
    #[inline]
    fn eval_tree_ds(&self, root: u32, ds: &Dataset, row: usize) -> u32 {
        let mut idx = root;
        loop {
            let n = &self.nodes[idx as usize];
            let go_pos = match n.kind {
                KIND_LEAF => return n.aux,
                KIND_HIGHER => match &ds.columns[n.attr as usize] {
                    ColumnData::Numerical(v) => {
                        let x = v[row];
                        if x.is_nan() {
                            n.missing_to_positive
                        } else {
                            x >= n.threshold
                        }
                    }
                    _ => n.missing_to_positive,
                },
                KIND_CONTAINS => match &ds.columns[n.attr as usize] {
                    ColumnData::Categorical(v) => {
                        let c = v[row];
                        if c == crate::dataset::MISSING_CAT {
                            n.missing_to_positive
                        } else {
                            bitmap_contains(
                                &self.bitmaps[n.aux as usize..(n.aux + n.aux_len) as usize],
                                c,
                            )
                        }
                    }
                    _ => n.missing_to_positive,
                },
                KIND_CONTAINS_SET => {
                    let col = &ds.columns[n.attr as usize];
                    if col.is_missing(row) {
                        n.missing_to_positive
                    } else {
                        let bm = &self.bitmaps[n.aux as usize..(n.aux + n.aux_len) as usize];
                        col.set_values(row)
                            .map(|items| items.iter().any(|&i| bitmap_contains(bm, i)))
                            .unwrap_or(n.missing_to_positive)
                    }
                }
                KIND_OBLIQUE => {
                    let mut acc = 0.0f32;
                    for &(a, w) in
                        &self.oblique[n.aux as usize..(n.aux + n.aux_len) as usize]
                    {
                        if let ColumnData::Numerical(v) = &ds.columns[a as usize] {
                            let x = v[row];
                            if !x.is_nan() {
                                acc += w * x;
                            }
                        }
                    }
                    acc >= n.threshold
                }
                KIND_IS_TRUE => match &ds.columns[n.attr as usize] {
                    ColumnData::Boolean(v) => match v[row] {
                        1 => true,
                        0 => false,
                        _ => n.missing_to_positive,
                    },
                    _ => n.missing_to_positive,
                },
                _ => unreachable!(),
            };
            idx = if go_pos { n.child } else { n.child + 1 };
        }
    }

    fn aggregate_leaves(&self, leaf_offsets: &[u32]) -> Vec<f64> {
        match &self.aggregate {
            Aggregate::RfAverage { num_classes, winner_take_all } => {
                let mut acc = vec![0.0f64; *num_classes];
                for &off in leaf_offsets {
                    let v = &self.leaf_values[off as usize..off as usize + self.leaf_dim];
                    if *winner_take_all {
                        let mut best = 0usize;
                        for (i, &x) in v.iter().enumerate().skip(1) {
                            if x > v[best] {
                                best = i;
                            }
                        }
                        acc[best] += 1.0;
                    } else {
                        for (a, &x) in acc.iter_mut().zip(v) {
                            *a += x as f64;
                        }
                    }
                }
                let n = leaf_offsets.len().max(1) as f64;
                for a in acc.iter_mut() {
                    *a /= n;
                }
                acc
            }
            Aggregate::RfRegression => {
                let sum: f64 = leaf_offsets
                    .iter()
                    .map(|&off| self.leaf_values[off as usize] as f64)
                    .sum();
                vec![sum / leaf_offsets.len().max(1) as f64]
            }
            Aggregate::Gbt { loss, dim, initial } => {
                let mut scores = initial.clone();
                for (i, &off) in leaf_offsets.iter().enumerate() {
                    scores[i % dim] += self.leaf_values[off as usize] as f64;
                }
                match loss {
                    GbtLoss::BinomialLogLikelihood => {
                        let p = crate::utils::stats::sigmoid(scores[0]);
                        vec![1.0 - p, p]
                    }
                    GbtLoss::MultinomialLogLikelihood => {
                        crate::utils::stats::softmax_in_place(&mut scores);
                        scores
                    }
                    GbtLoss::SquaredError => scores,
                }
            }
        }
    }
}

impl InferenceEngine for FlatEngine {
    fn name(&self) -> String {
        let kind = match self.aggregate {
            Aggregate::RfAverage { .. } | Aggregate::RfRegression => "RandomForest",
            Aggregate::Gbt { .. } => "GradientBoostedTrees",
        };
        format!("{kind}OptPred") // YDF's name for its flat SoA engine
    }

    fn predict_row(&self, obs: &Observation) -> Vec<f64> {
        let leaves: Vec<u32> =
            self.roots.iter().map(|&r| self.eval_tree_row(r, obs)).collect();
        self.aggregate_leaves(&leaves)
    }

    fn predict_dataset(&self, ds: &Dataset) -> Vec<Vec<f64>> {
        let mut out = Vec::with_capacity(ds.num_rows());
        let mut leaves = vec![0u32; self.roots.len()];
        for row in 0..ds.num_rows() {
            for (slot, &root) in leaves.iter_mut().zip(&self.roots) {
                *slot = self.eval_tree_ds(root, ds, row);
            }
            out.push(self.aggregate_leaves(&leaves));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::learner::gbt::GbtConfig;
    use crate::learner::random_forest::RandomForestConfig;
    use crate::learner::{GradientBoostedTreesLearner, Learner, RandomForestLearner};

    fn close(a: &[f64], b: &[f64]) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < 1e-9, "{a:?} vs {b:?}");
        }
    }

    #[test]
    fn flat_matches_naive_gbt() {
        let ds = synthetic::adult_like(200, 131);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 10;
        cfg.max_depth = 4;
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let flat = FlatEngine::compile(model.as_ref()).unwrap();
        for r in 0..50 {
            close(&flat.predict_row(&ds.row(r)), &model.predict_ds_row(&ds, r));
        }
        let batch = flat.predict_dataset(&ds);
        for r in 0..50 {
            close(&batch[r], &model.predict_ds_row(&ds, r));
        }
    }

    #[test]
    fn flat_matches_naive_rf_with_missing() {
        let ds = synthetic::adult_like(200, 133);
        let mut cfg = RandomForestConfig::new("income");
        cfg.num_trees = 8;
        cfg.compute_oob = false;
        let model = RandomForestLearner::new(cfg).train(&ds).unwrap();
        let flat = FlatEngine::compile(model.as_ref()).unwrap();
        for r in 0..ds.num_rows() {
            close(&flat.predict_row(&ds.row(r)), &model.predict_ds_row(&ds, r));
        }
    }

    #[test]
    fn flat_matches_naive_oblique_model() {
        let ds = synthetic::adult_like(150, 137);
        let mut cfg = GbtConfig::benchmark_rank1("income");
        cfg.num_trees = 6;
        let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();
        let flat = FlatEngine::compile(model.as_ref()).unwrap();
        for r in 0..ds.num_rows() {
            close(&flat.predict_row(&ds.row(r)), &model.predict_ds_row(&ds, r));
        }
    }

    #[test]
    fn linear_model_not_compilable() {
        let ds = synthetic::adult_like(50, 139);
        let model = crate::learner::LinearLearner::default_config("income")
            .train(&ds)
            .unwrap();
        assert!(FlatEngine::compile(model.as_ref()).is_none());
    }
}
