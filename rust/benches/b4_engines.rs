//! Appendix B.4: the model inference benchmark — every compatible engine
//! timed over the dataset, µs/example (the report the CLI's
//! `benchmark_inference` prints). Includes the PJRT/XLA engine when the
//! artifact is available.
//!
//! Run: cargo bench --bench b4_engines

use ydf::dataset::synthetic;
use ydf::inference::{benchmark_inference_report, InferenceEngine};
use ydf::learner::gbt::GbtConfig;
use ydf::learner::{GradientBoostedTreesLearner, Learner};

fn main() {
    // Numerical-only dataset so every engine (incl. PJRT) is compatible.
    let spec = synthetic::spec_by_name("Wilt").unwrap();
    let opts = synthetic::GenOptions { max_examples: 2000, ..Default::default() };
    let ds = synthetic::generate(spec, 20230806, &opts);
    let mut cfg = GbtConfig::new("label");
    cfg.num_trees = 50;
    cfg.max_depth = 5;
    let model = GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap();

    println!("{}", benchmark_inference_report(model.as_ref(), &ds, 20));

    // PJRT/XLA engine (lossy compilation, §3.7), when artifacts exist.
    match ydf::runtime::Runtime::cpu()
        .and_then(|rt| ydf::inference::pjrt::PjrtEngine::compile(model.as_ref(), &rt))
    {
        Ok(engine) => {
            let t0 = std::time::Instant::now();
            let runs = 5;
            for _ in 0..runs {
                std::hint::black_box(engine.predict_dataset(&ds));
            }
            let us = t0.elapsed().as_secs_f64() / (runs * ds.num_rows()) as f64 * 1e6;
            println!("  {:<42} {us:>10.3} us/example", engine.name());
        }
        Err(e) => println!("  (PJRT engine skipped: {e})"),
    }
}
