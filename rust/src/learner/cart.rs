//! CART learner (Breiman et al. 1984): a single decision tree with
//! validation-set pruning. One of the built-in learners of §3.1.

use super::decision_tree::{grow_tree, AttrSampling, GrowingStrategy, TreeConfig};
use super::{classification_labels, feature_columns, regression_targets, Learner};
use crate::dataset::Dataset;
use crate::model::forest::RandomForestModel;
use crate::model::tree::DecisionTree;
use crate::model::{Model, Task};
use crate::splitter::score::Labels;
use crate::splitter::{ColumnIndex, RowArena, SplitEngine, SplitterConfig};
use crate::utils::rng::Rng;
use std::collections::HashMap;
use std::sync::Arc;

/// CART configuration.
#[derive(Clone, Debug)]
pub struct CartConfig {
    pub label: String,
    pub task: Task,
    pub max_depth: usize,
    pub min_examples: usize,
    pub splitter: SplitterConfig,
    /// Fraction of examples used for reduced-error pruning (0 disables).
    pub pruning_ratio: f64,
    /// Threads for the per-node split search (CART considers every
    /// feature at every node, so the feature-parallel `SplitEngine` path
    /// applies directly; bit-identical to single-threaded). Defaults to
    /// [`super::train_threads`] (the `YDF_TRAIN_THREADS` override, else 1).
    pub num_threads: usize,
    pub seed: u64,
}

impl CartConfig {
    pub fn new(label: &str) -> CartConfig {
        CartConfig {
            label: label.to_string(),
            task: Task::Classification,
            max_depth: 16,
            min_examples: 5,
            splitter: SplitterConfig::default(),
            pruning_ratio: 0.1,
            num_threads: super::train_threads(),
            seed: 9876,
        }
    }
}

/// A CART model is a Random Forest model with a single tree and probability
/// averaging — the LEARNER–MODEL separation (§3.1) lets two learners share
/// one model type, so all tree tooling applies.
pub struct CartLearner {
    pub config: CartConfig,
}

impl CartLearner {
    pub fn new(config: CartConfig) -> Self {
        CartLearner { config }
    }

    pub fn default_config(label: &str) -> Self {
        CartLearner::new(CartConfig::new(label))
    }
}

pub fn factory(
    label: &str,
    params: &HashMap<String, String>,
) -> Result<Box<dyn Learner>, String> {
    let mut cfg = CartConfig::new(label);
    cfg.max_depth = super::parse_param(params, "max_depth", cfg.max_depth)?;
    cfg.min_examples = super::parse_param(params, "min_examples", cfg.min_examples)?;
    cfg.seed = super::parse_param(params, "seed", cfg.seed)?;
    cfg.num_threads = super::parse_param(params, "num_threads", cfg.num_threads)?;
    if let Some(t) = params.get("task") {
        cfg.task = match t.as_str() {
            "CLASSIFICATION" => Task::Classification,
            "REGRESSION" => Task::Regression,
            other => return Err(format!("unknown task '{other}'")),
        };
    }
    Ok(Box::new(CartLearner::new(cfg)))
}

/// Reduced-error pruning: replace internal nodes by leaves whenever that
/// does not hurt accuracy/SSE on a held-out set.
fn prune(
    tree: &mut DecisionTree,
    ds: &Dataset,
    rows: &[u32],
    task: Task,
    labels: &[u32],
    targets: &[f32],
) {
    // For each node, collect the held-out rows that reach it, bottom-up.
    fn route(tree: &DecisionTree, ds: &Dataset, rows: &[u32]) -> Vec<Vec<u32>> {
        let mut reach: Vec<Vec<u32>> = vec![Vec::new(); tree.nodes.len()];
        for &r in rows {
            let mut idx = 0usize;
            loop {
                reach[idx].push(r);
                let node = &tree.nodes[idx];
                match &node.condition {
                    None => break,
                    Some(c) => {
                        let pos = c
                            .evaluate_ds(ds, r as usize)
                            .unwrap_or(node.missing_to_positive);
                        idx = if pos { node.positive as usize } else { node.negative as usize };
                    }
                }
            }
        }
        reach
    }
    let reach = route(tree, ds, rows);

    // Node error if converted to a leaf vs error of its subtree.
    fn leaf_error(
        value: &[f32],
        rows: &[u32],
        task: Task,
        labels: &[u32],
        targets: &[f32],
    ) -> f64 {
        match task {
            Task::Classification => {
                let mut best = 0usize;
                for (i, &v) in value.iter().enumerate().skip(1) {
                    if v > value[best] {
                        best = i;
                    }
                }
                rows.iter().filter(|&&r| labels[r as usize] != best as u32).count() as f64
            }
            Task::Regression => rows
                .iter()
                .map(|&r| {
                    let e = value[0] as f64 - targets[r as usize] as f64;
                    e * e
                })
                .sum(),
        }
    }

    fn subtree_error(
        tree: &DecisionTree,
        idx: usize,
        reach: &[Vec<u32>],
        task: Task,
        labels: &[u32],
        targets: &[f32],
    ) -> f64 {
        let node = &tree.nodes[idx];
        if node.is_leaf() {
            leaf_error(&node.value, &reach[idx], task, labels, targets)
        } else {
            subtree_error(tree, node.positive as usize, reach, task, labels, targets)
                + subtree_error(tree, node.negative as usize, reach, task, labels, targets)
        }
    }

    // The leaf payload each internal node would get: recompute from its
    // children (weighted by training counts).
    fn merged_value(tree: &DecisionTree, idx: usize) -> (Vec<f32>, f64) {
        let node = &tree.nodes[idx];
        if node.is_leaf() {
            return (node.value.clone(), node.num_examples);
        }
        let (pv, pn) = merged_value(tree, node.positive as usize);
        let (nv, nn) = merged_value(tree, node.negative as usize);
        let total = pn + nn;
        let value = pv
            .iter()
            .zip(&nv)
            .map(|(&a, &b)| ((a as f64 * pn + b as f64 * nn) / total.max(1.0)) as f32)
            .collect();
        (value, total)
    }

    // Bottom-up: visit nodes in decreasing index order (children always
    // have larger indices than parents in our arena construction).
    for idx in (0..tree.nodes.len()).rev() {
        if tree.nodes[idx].is_leaf() || reach[idx].is_empty() {
            continue;
        }
        let (value, total) = merged_value(tree, idx);
        let as_leaf = leaf_error(&value, &reach[idx], task, labels, targets);
        let as_subtree = subtree_error(tree, idx, &reach, task, labels, targets);
        if as_leaf <= as_subtree {
            let node = &mut tree.nodes[idx];
            node.condition = None;
            node.value = value;
            node.num_examples = total;
            node.score = 0.0;
        }
    }
}

impl Learner for CartLearner {
    fn name(&self) -> &'static str {
        "CART"
    }

    fn label(&self) -> &str {
        &self.config.label
    }

    fn train_with_valid(
        &self,
        ds: &Dataset,
        valid: Option<&Dataset>,
    ) -> Result<Box<dyn Model>, String> {
        let cfg = &self.config;
        let (label_col, class_labels, reg_targets) = match cfg.task {
            Task::Classification => {
                let (c, l) = classification_labels(ds, &cfg.label)?;
                (c, l, vec![])
            }
            Task::Regression => {
                let (c, t) = regression_targets(ds, &cfg.label)?;
                (c, vec![], t)
            }
        };
        let features = feature_columns(ds, label_col);
        let num_classes = ds.spec.columns[label_col].vocab_size();

        // Split off a pruning set (or use the provided validation set).
        let (train_rows, prune_rows): (Vec<u32>, Vec<u32>) =
            if valid.is_none() && cfg.pruning_ratio > 0.0 && ds.num_rows() >= 20 {
                let (tr, va) = ds.train_valid_split(cfg.pruning_ratio, cfg.seed);
                (tr.iter().map(|&r| r as u32).collect(), va.iter().map(|&r| r as u32).collect())
            } else {
                ((0..ds.num_rows() as u32).collect(), vec![])
            };

        let labels_view = match cfg.task {
            Task::Classification => {
                Labels::Classification { labels: &class_labels, num_classes }
            }
            Task::Regression => Labels::Regression { targets: &reg_targets },
        };
        let tree_cfg = TreeConfig {
            max_depth: cfg.max_depth,
            min_examples: cfg.min_examples,
            splitter: cfg.splitter.clone(),
            growing: GrowingStrategy::Local,
            attr_sampling: AttrSampling::All,
        };
        let mut engine =
            SplitEngine::new(Arc::new(ColumnIndex::new(ds)), cfg.num_threads);
        let mut arena = RowArena::new();
        let mut rng = Rng::seed_from_u64(cfg.seed);
        let t_span = crate::obs::trace::begin();
        let t_grow = std::time::Instant::now();
        let mut tree = grow_tree(
            ds,
            &train_rows,
            &labels_view,
            &features,
            &tree_cfg,
            &mut engine,
            &mut arena,
            &mut rng,
        );
        let grow_us = t_grow.elapsed().as_secs_f64() * 1e6;
        crate::obs::metrics()
            .counter_with(
                "ydf_train_trees_total",
                "Trees grown during training, by learner.",
                &[("learner", "cart")],
            )
            .inc();
        crate::obs::trace::end(t_span, "train_tree", || {
            use crate::obs::trace::ArgValue;
            vec![
                ("learner", ArgValue::Str("cart".to_string())),
                ("nodes", ArgValue::U64(tree.nodes.len() as u64)),
                ("us", ArgValue::F64(grow_us)),
            ]
        });
        let nodes_before_prune = tree.nodes.len();

        let t_prune = crate::obs::trace::begin();
        if !prune_rows.is_empty() {
            prune(&mut tree, ds, &prune_rows, cfg.task, &class_labels, &reg_targets);
        } else if let Some(v) = valid {
            let (v_labels, v_targets) = match cfg.task {
                Task::Classification => (classification_labels(v, &cfg.label)?.1, vec![]),
                Task::Regression => (vec![], regression_targets(v, &cfg.label)?.1),
            };
            let rows: Vec<u32> = (0..v.num_rows() as u32).collect();
            prune(&mut tree, v, &rows, cfg.task, &v_labels, &v_targets);
        }
        crate::obs::trace::end(t_prune, "prune", || {
            use crate::obs::trace::ArgValue;
            vec![
                ("nodes_before", ArgValue::U64(nodes_before_prune as u64)),
                ("nodes_after", ArgValue::U64(tree.nodes.len() as u64)),
            ]
        });
        crate::ydf_info!(
            "cart: grew tree with {nodes_before_prune} nodes in {grow_us:.0} us, \
             {} nodes after pruning",
            tree.nodes.len()
        );

        Ok(Box::new(RandomForestModel {
            spec: ds.spec.clone(),
            label_col,
            task: cfg.task,
            trees: vec![tree],
            winner_take_all: false,
            oob_evaluation: None,
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::evaluation_free_accuracy;

    #[test]
    fn single_tree_learns() {
        let ds = synthetic::adult_like(500, 41);
        let model = CartLearner::default_config("income").train(&ds).unwrap();
        let acc = evaluation_free_accuracy(model.as_ref(), &ds);
        assert!(acc > 0.72, "accuracy {acc}");
        let rf = model.as_any().downcast_ref::<RandomForestModel>().unwrap();
        assert_eq!(rf.trees.len(), 1);
    }

    #[test]
    fn pruning_shrinks_overfit_tree() {
        let ds = synthetic::adult_like(400, 43);
        let mut cfg = CartConfig::new("income");
        cfg.max_depth = 30;
        cfg.min_examples = 1;
        cfg.pruning_ratio = 0.0;
        let unpruned = CartLearner::new(cfg.clone()).train(&ds).unwrap();
        cfg.pruning_ratio = 0.3;
        let pruned = CartLearner::new(cfg).train(&ds).unwrap();
        let nodes = |m: &dyn Model| {
            m.as_any().downcast_ref::<RandomForestModel>().unwrap().trees[0].num_nodes()
        };
        assert!(
            nodes(pruned.as_ref()) < nodes(unpruned.as_ref()),
            "{} vs {}",
            nodes(pruned.as_ref()),
            nodes(unpruned.as_ref())
        );
    }

    #[test]
    fn regression_cart() {
        let ds = synthetic::adult_like(300, 47);
        let mut cfg = CartConfig::new("hours_per_week");
        cfg.task = Task::Regression;
        let model = CartLearner::new(cfg).train(&ds).unwrap();
        let p = model.predict_ds_row(&ds, 0);
        assert_eq!(p.len(), 1);
        assert!(p[0].is_finite());
    }
}
