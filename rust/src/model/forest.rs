//! Decision forest models: Random Forest (Breiman 2001) and Gradient
//! Boosted Trees (Friedman 2001).
//!
//! Both are *models only* — training logic lives in `learner::` per the
//! LEARNER–MODEL separation (§3.1): different learners (e.g. the classic
//! in-memory learner and the distributed learner) produce the same model
//! structures, and all post-training tools apply to both.

use super::tree::DecisionTree;
use super::{Model, SelfEvaluation, Task, VariableImportance};
use crate::dataset::{DataSpec, Dataset, Observation};
use crate::utils::json::Json;
use crate::utils::stats::{sigmoid, softmax_in_place};
use std::collections::BTreeMap;

/// Random Forest: bagged deep trees, prediction = average of per-tree class
/// distributions (or vote when `winner_take_all`).
#[derive(Clone)]
pub struct RandomForestModel {
    pub spec: DataSpec,
    pub label_col: usize,
    pub task: Task,
    pub trees: Vec<DecisionTree>,
    /// Majority vote instead of probability averaging.
    pub winner_take_all: bool,
    /// Out-of-bag self-evaluation (§3.6), when computed by the learner.
    pub oob_evaluation: Option<SelfEvaluation>,
}

impl RandomForestModel {
    fn aggregate<'a, I: Iterator<Item = &'a [f32]>>(&self, leaves: I) -> Vec<f64> {
        let dim = match self.task {
            Task::Classification => self.spec.columns[self.label_col].vocab_size(),
            Task::Regression => 1,
        };
        let mut acc = vec![0.0f64; dim];
        let mut count = 0usize;
        for leaf in leaves {
            if self.winner_take_all && self.task == Task::Classification {
                // First-wins tie rule, shared with every inference engine.
                let mut best = 0usize;
                for (i, &v) in leaf.iter().enumerate().skip(1) {
                    if v > leaf[best] {
                        best = i;
                    }
                }
                acc[best] += 1.0;
            } else {
                for (a, &v) in acc.iter_mut().zip(leaf) {
                    *a += v as f64;
                }
            }
            count += 1;
        }
        if count > 0 {
            for a in acc.iter_mut() {
                *a /= count as f64;
            }
        }
        acc
    }
}

impl Model for RandomForestModel {
    fn model_type(&self) -> &'static str {
        "RANDOM_FOREST"
    }
    fn task(&self) -> Task {
        self.task
    }
    fn spec(&self) -> &DataSpec {
        &self.spec
    }
    fn label_col(&self) -> usize {
        self.label_col
    }

    fn input_features(&self) -> Vec<usize> {
        used_attributes(&self.trees)
    }

    fn predict_row(&self, obs: &Observation) -> Vec<f64> {
        self.aggregate(self.trees.iter().map(|t| t.eval_row(obs).value.as_slice()))
    }

    fn predict_ds_row(&self, ds: &Dataset, row: usize) -> Vec<f64> {
        self.aggregate(self.trees.iter().map(|t| t.eval_ds(ds, row).value.as_slice()))
    }

    fn describe(&self) -> String {
        super::describe::describe_forest(
            self.model_type(),
            self.task,
            &self.spec,
            self.label_col,
            &self.trees,
            self.self_evaluation(),
            &self.variable_importances(),
        )
    }

    fn variable_importances(&self) -> Vec<VariableImportance> {
        variable_importances(&self.trees, &self.spec)
    }

    fn self_evaluation(&self) -> Option<&SelfEvaluation> {
        self.oob_evaluation.as_ref()
    }

    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("format_version", Json::Num(super::io::MODEL_FORMAT_VERSION as f64))
            .set("model_type", Json::Str(self.model_type().into()))
            .set("task", Json::Str(self.task.name().into()))
            .set("label_col", Json::Num(self.label_col as f64))
            .set("winner_take_all", Json::Bool(self.winner_take_all))
            .set("spec", self.spec.to_json())
            .set("trees", Json::Arr(self.trees.iter().map(|t| t.to_json()).collect()));
        if let Some(e) = &self.oob_evaluation {
            let mut ej = Json::obj();
            ej.set("metric", Json::Str(e.metric.clone()))
                .set("value", Json::Num(e.value))
                .set("num_examples", Json::Num(e.num_examples as f64));
            j.set("self_evaluation", ej);
        }
        j
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// The GBT loss, fixed at training time and needed at inference to map the
/// accumulated scores into predictions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GbtLoss {
    /// Binary classification (Appendix B.2: BINOMIAL_LOG_LIKELIHOOD).
    BinomialLogLikelihood,
    /// Multi-class classification: one tree per class per iteration.
    MultinomialLogLikelihood,
    /// Regression.
    SquaredError,
}

impl GbtLoss {
    pub fn name(&self) -> &'static str {
        match self {
            GbtLoss::BinomialLogLikelihood => "BINOMIAL_LOG_LIKELIHOOD",
            GbtLoss::MultinomialLogLikelihood => "MULTINOMIAL_LOG_LIKELIHOOD",
            GbtLoss::SquaredError => "SQUARED_ERROR",
        }
    }

    pub fn from_name(s: &str) -> Option<GbtLoss> {
        match s {
            "BINOMIAL_LOG_LIKELIHOOD" => Some(GbtLoss::BinomialLogLikelihood),
            "MULTINOMIAL_LOG_LIKELIHOOD" => Some(GbtLoss::MultinomialLogLikelihood),
            "SQUARED_ERROR" => Some(GbtLoss::SquaredError),
            _ => None,
        }
    }
}

/// Gradient Boosted Trees: sum of shrunken tree outputs added to an initial
/// prediction, mapped through the loss's link function.
#[derive(Clone)]
pub struct GradientBoostedTreesModel {
    pub spec: DataSpec,
    pub label_col: usize,
    pub task: Task,
    pub loss: GbtLoss,
    /// Trees, grouped by iteration: `trees[i * trees_per_iter + k]` is the
    /// tree for output dimension `k` at iteration `i`. Leaf values are
    /// already multiplied by the shrinkage.
    pub trees: Vec<DecisionTree>,
    pub trees_per_iter: usize,
    /// Initial prediction (prior log-odds / mean), one per output dim.
    pub initial_predictions: Vec<f64>,
    /// Validation loss recorded by early stopping (Appendix B.2 report).
    pub validation_loss: Option<f64>,
    pub self_eval: Option<SelfEvaluation>,
}

impl GradientBoostedTreesModel {
    /// Raw accumulated scores (log-odds / regression value), before the
    /// link function. The inference engines reproduce exactly this.
    pub fn decision_scores_row(&self, obs: &Observation) -> Vec<f64> {
        let mut scores = self.initial_predictions.clone();
        for (i, t) in self.trees.iter().enumerate() {
            scores[i % self.trees_per_iter] += t.eval_row(obs).value[0] as f64;
        }
        scores
    }

    pub fn decision_scores_ds(&self, ds: &Dataset, row: usize) -> Vec<f64> {
        let mut scores = self.initial_predictions.clone();
        for (i, t) in self.trees.iter().enumerate() {
            scores[i % self.trees_per_iter] += t.eval_ds(ds, row).value[0] as f64;
        }
        scores
    }

    /// Maps raw scores to the prediction space.
    pub fn activation(&self, scores: &[f64]) -> Vec<f64> {
        match self.loss {
            GbtLoss::BinomialLogLikelihood => {
                let p = sigmoid(scores[0]);
                vec![1.0 - p, p]
            }
            GbtLoss::MultinomialLogLikelihood => {
                let mut probs = scores.to_vec();
                softmax_in_place(&mut probs);
                probs
            }
            GbtLoss::SquaredError => scores.to_vec(),
        }
    }

    pub fn num_iterations(&self) -> usize {
        self.trees.len() / self.trees_per_iter.max(1)
    }
}

impl Model for GradientBoostedTreesModel {
    fn model_type(&self) -> &'static str {
        "GRADIENT_BOOSTED_TREES"
    }
    fn task(&self) -> Task {
        self.task
    }
    fn spec(&self) -> &DataSpec {
        &self.spec
    }
    fn label_col(&self) -> usize {
        self.label_col
    }

    fn input_features(&self) -> Vec<usize> {
        used_attributes(&self.trees)
    }

    fn predict_row(&self, obs: &Observation) -> Vec<f64> {
        self.activation(&self.decision_scores_row(obs))
    }

    fn predict_ds_row(&self, ds: &Dataset, row: usize) -> Vec<f64> {
        self.activation(&self.decision_scores_ds(ds, row))
    }

    fn describe(&self) -> String {
        let mut s = super::describe::describe_forest(
            self.model_type(),
            self.task,
            &self.spec,
            self.label_col,
            &self.trees,
            self.self_eval.as_ref(),
            &self.variable_importances(),
        );
        s.push_str(&format!(
            "\nLoss: {}\nNumber of trees per iteration: {}\n",
            self.loss.name(),
            self.trees_per_iter
        ));
        if let Some(vl) = self.validation_loss {
            s.push_str(&format!("Validation loss value: {vl:.6}\n"));
        }
        s
    }

    fn variable_importances(&self) -> Vec<VariableImportance> {
        variable_importances(&self.trees, &self.spec)
    }

    fn self_evaluation(&self) -> Option<&SelfEvaluation> {
        self.self_eval.as_ref()
    }

    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("format_version", Json::Num(super::io::MODEL_FORMAT_VERSION as f64))
            .set("model_type", Json::Str(self.model_type().into()))
            .set("task", Json::Str(self.task.name().into()))
            .set("label_col", Json::Num(self.label_col as f64))
            .set("loss", Json::Str(self.loss.name().into()))
            .set("trees_per_iter", Json::Num(self.trees_per_iter as f64))
            .set("initial_predictions", Json::from_f64s(&self.initial_predictions))
            .set("spec", self.spec.to_json())
            .set("trees", Json::Arr(self.trees.iter().map(|t| t.to_json()).collect()));
        if let Some(vl) = self.validation_loss {
            j.set("validation_loss", Json::Num(vl));
        }
        j
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

/// Attributes referenced by any tree condition, sorted and deduplicated.
pub fn used_attributes(trees: &[DecisionTree]) -> Vec<usize> {
    let mut attrs: Vec<usize> = trees
        .iter()
        .flat_map(|t| {
            t.nodes
                .iter()
                .filter_map(|n| n.condition.as_ref())
                .flat_map(|c| c.attributes())
        })
        .collect();
    attrs.sort_unstable();
    attrs.dedup();
    attrs
}

/// Structural variable importances over a set of trees.
pub fn variable_importances(trees: &[DecisionTree], spec: &DataSpec) -> Vec<VariableImportance> {
    let mut as_root: BTreeMap<usize, f64> = BTreeMap::new();
    let mut num_nodes: BTreeMap<usize, f64> = BTreeMap::new();
    let mut sum_score: BTreeMap<usize, f64> = BTreeMap::new();
    let mut min_depth_sum: BTreeMap<usize, f64> = BTreeMap::new();
    let mut min_depth_count: BTreeMap<usize, f64> = BTreeMap::new();
    for t in trees {
        if let Some(root) = t.nodes.first() {
            if let Some(c) = &root.condition {
                for a in c.attributes() {
                    *as_root.entry(a).or_insert(0.0) += 1.0;
                }
            }
        }
        let mut per_tree_min_depth: BTreeMap<usize, usize> = BTreeMap::new();
        t.visit_internal(|n, depth| {
            if let Some(c) = &n.condition {
                for a in c.attributes() {
                    *num_nodes.entry(a).or_insert(0.0) += 1.0;
                    *sum_score.entry(a).or_insert(0.0) += n.score as f64;
                    per_tree_min_depth
                        .entry(a)
                        .and_modify(|d| *d = (*d).min(depth))
                        .or_insert(depth);
                }
            }
        });
        for (a, d) in per_tree_min_depth {
            *min_depth_sum.entry(a).or_insert(0.0) += d as f64;
            *min_depth_count.entry(a).or_insert(0.0) += 1.0;
        }
    }
    let to_vi = |kind: &'static str, m: BTreeMap<usize, f64>| -> VariableImportance {
        let mut values: Vec<(String, f64)> = m
            .into_iter()
            .map(|(a, v)| (spec.columns[a].name.clone(), v))
            .collect();
        values.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
        VariableImportance { kind, values }
    };
    let inv_mean_min_depth: BTreeMap<usize, f64> = min_depth_sum
        .iter()
        .map(|(&a, &s)| (a, 1.0 / (1.0 + s / min_depth_count[&a])))
        .collect();
    vec![
        to_vi("NUM_AS_ROOT", as_root),
        to_vi("NUM_NODES", num_nodes),
        to_vi("SUM_SCORE", sum_score),
        to_vi("INV_MEAN_MIN_DEPTH", inv_mean_min_depth),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::dataspec::ColumnSpec;
    use crate::dataset::AttrValue;
    use crate::model::tree::{Condition, Node};

    fn spec2() -> DataSpec {
        DataSpec {
            columns: vec![
                ColumnSpec::numerical("x"),
                ColumnSpec::categorical("y", vec!["no".into(), "yes".into()]),
            ],
        }
    }

    fn stump(threshold: f32, lo: Vec<f32>, hi: Vec<f32>) -> DecisionTree {
        DecisionTree {
            nodes: vec![
                Node {
                    condition: Some(Condition::Higher { attr: 0, threshold }),
                    positive: 1,
                    negative: 2,
                    missing_to_positive: false,
                    value: vec![],
                    num_examples: 10.0,
                    score: 1.0,
                },
                Node::leaf(hi, 5.0),
                Node::leaf(lo, 5.0),
            ],
        }
    }

    #[test]
    fn rf_averages_probabilities() {
        let m = RandomForestModel {
            spec: spec2(),
            label_col: 1,
            task: Task::Classification,
            trees: vec![
                stump(0.0, vec![0.8, 0.2], vec![0.2, 0.8]),
                stump(0.0, vec![0.6, 0.4], vec![0.4, 0.6]),
            ],
            winner_take_all: false,
            oob_evaluation: None,
        };
        let p = m.predict_row(&vec![AttrValue::Num(1.0), AttrValue::Missing]);
        assert!((p[1] - 0.7).abs() < 1e-6);
        let p = m.predict_row(&vec![AttrValue::Num(-1.0), AttrValue::Missing]);
        assert!((p[1] - 0.3).abs() < 1e-6);
    }

    #[test]
    fn rf_winner_take_all_votes() {
        let m = RandomForestModel {
            spec: spec2(),
            label_col: 1,
            task: Task::Classification,
            trees: vec![
                stump(0.0, vec![0.4, 0.6], vec![0.2, 0.8]),
                stump(0.0, vec![0.9, 0.1], vec![0.2, 0.8]),
                stump(0.0, vec![0.9, 0.1], vec![0.2, 0.8]),
            ],
            winner_take_all: true,
            oob_evaluation: None,
        };
        let p = m.predict_row(&vec![AttrValue::Num(-1.0), AttrValue::Missing]);
        // Votes: yes, no, no -> [2/3, 1/3]
        assert!((p[0] - 2.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn gbt_binary_sigmoid() {
        let m = GradientBoostedTreesModel {
            spec: spec2(),
            label_col: 1,
            task: Task::Classification,
            loss: GbtLoss::BinomialLogLikelihood,
            trees: vec![stump(0.0, vec![-1.0], vec![1.0]), stump(0.0, vec![-0.5], vec![0.5])],
            trees_per_iter: 1,
            initial_predictions: vec![0.2],
            validation_loss: Some(0.5),
            self_eval: None,
        };
        let p = m.predict_row(&vec![AttrValue::Num(1.0), AttrValue::Missing]);
        let expected = sigmoid(0.2 + 1.0 + 0.5);
        assert!((p[1] - expected).abs() < 1e-6);
        assert!((p[0] + p[1] - 1.0).abs() < 1e-12);
        assert_eq!(m.num_iterations(), 2);
    }

    #[test]
    fn gbt_multiclass_softmax() {
        let spec = DataSpec {
            columns: vec![
                ColumnSpec::numerical("x"),
                ColumnSpec::categorical("y", vec!["a".into(), "b".into(), "c".into()]),
            ],
        };
        let m = GradientBoostedTreesModel {
            spec,
            label_col: 1,
            task: Task::Classification,
            loss: GbtLoss::MultinomialLogLikelihood,
            trees: vec![
                stump(0.0, vec![0.1], vec![2.0]), // class a
                stump(0.0, vec![0.1], vec![0.0]), // class b
                stump(0.0, vec![0.1], vec![-1.0]), // class c
            ],
            trees_per_iter: 3,
            initial_predictions: vec![0.0, 0.0, 0.0],
            validation_loss: None,
            self_eval: None,
        };
        let p = m.predict_row(&vec![AttrValue::Num(1.0), AttrValue::Missing]);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p[0] > p[1] && p[1] > p[2]);
    }

    #[test]
    fn variable_importance_counts() {
        let trees = vec![
            stump(0.0, vec![0.5, 0.5], vec![0.5, 0.5]),
            stump(1.0, vec![0.5, 0.5], vec![0.5, 0.5]),
        ];
        let vis = variable_importances(&trees, &spec2());
        let as_root = vis.iter().find(|v| v.kind == "NUM_AS_ROOT").unwrap();
        assert_eq!(as_root.values, vec![("x".to_string(), 2.0)]);
        let nodes = vis.iter().find(|v| v.kind == "NUM_NODES").unwrap();
        assert_eq!(nodes.values[0].1, 2.0);
    }

    #[test]
    fn used_attributes_dedup() {
        let trees =
            vec![stump(0.0, vec![0.5], vec![0.5]), stump(2.0, vec![0.5], vec![0.5])];
        assert_eq!(used_attributes(&trees), vec![0]);
    }
}
