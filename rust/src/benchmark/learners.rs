//! The 16 learners of the KDD'23 benchmark (§5.1, Figure 6), emulated
//! inside this library.
//!
//! Baseline libraries are reproduced by their *algorithmic configurations*
//! — the factors §5.5 credits for the observed differences:
//!
//! * **XGBoost-style** — exact numerical splits, one-hot categorical
//!   handling, depth-wise growth, hessian gain with L2.
//! * **LightGBM-style** — quantile-histogram splits, leaf-wise (best-first
//!   global) growth, native categorical (CART ordering).
//! * **scikit-learn-RF-style** — deep trees, one-hot categoricals,
//!   probability averaging.
//! * **TF-BoostedTrees-style** — coarse histogram + one-hot + heavy
//!   regularization (the configuration whose accuracy trails a linear
//!   model in the paper).
//! * **TF-Linear** — the linear learner.

use crate::learner::decision_tree::GrowingStrategy;
use crate::learner::gbt::{GbtConfig, GradientBoostedTreesLearner};
use crate::learner::linear::{LinearConfig, LinearLearner};
use crate::learner::random_forest::{RandomForestConfig, RandomForestLearner};
use crate::learner::Learner;
use crate::metalearner::{TunerLearner, TunerScoring};
use crate::splitter::{CategoricalSplit, NumericalSplit};

/// Scale knobs so the suite fits the available budget: the paper fixes
/// 500 trees and 300 tuning trials; the defaults here are scaled down and
/// reported with the results.
#[derive(Clone, Copy, Debug)]
pub struct LearnerScale {
    pub num_trees: usize,
    pub tuner_trials: usize,
}

impl Default for LearnerScale {
    fn default() -> Self {
        LearnerScale { num_trees: 30, tuner_trials: 4 }
    }
}

fn ydf_gbt_default(label: &str, s: LearnerScale) -> GbtConfig {
    let mut cfg = GbtConfig::new(label);
    cfg.num_trees = s.num_trees;
    cfg
}

fn ydf_rf_default(label: &str, s: LearnerScale) -> RandomForestConfig {
    let mut cfg = RandomForestConfig::new(label);
    cfg.num_trees = s.num_trees;
    cfg.compute_oob = false;
    cfg
}

fn lgbm_gbt(label: &str, s: LearnerScale) -> GbtConfig {
    let mut cfg = GbtConfig::new(label);
    cfg.num_trees = s.num_trees;
    cfg.splitter.numerical = NumericalSplit::Histogram { bins: 255 };
    cfg.splitter.categorical = CategoricalSplit::Cart; // native categorical
    cfg.growing = GrowingStrategy::BestFirstGlobal { max_num_leaves: 31 };
    cfg.max_depth = usize::MAX;
    cfg.min_examples = 20; // LightGBM min_data_in_leaf default
    cfg
}

fn xgb_gbt(label: &str, s: LearnerScale) -> GbtConfig {
    let mut cfg = GbtConfig::new(label);
    cfg.num_trees = s.num_trees;
    cfg.splitter.numerical = NumericalSplit::ExactInSort; // XGB exact
    cfg.splitter.categorical = CategoricalSplit::OneHot; // no native cats
    cfg.use_hessian_gain = true;
    cfg.l2 = 1.0;
    cfg.max_depth = 6;
    cfg.min_examples = 1;
    cfg
}

fn sklearn_rf(label: &str, s: LearnerScale) -> RandomForestConfig {
    let mut cfg = RandomForestConfig::new(label);
    cfg.num_trees = s.num_trees;
    cfg.max_depth = usize::MAX; // sklearn grows to purity by default
    cfg.min_examples = 1;
    cfg.splitter.categorical = CategoricalSplit::OneHot;
    cfg.winner_take_all = false; // sklearn averages probabilities
    cfg.compute_oob = false;
    cfg
}

fn tf_ebt(label: &str, s: LearnerScale) -> GbtConfig {
    let mut cfg = GbtConfig::new(label);
    cfg.num_trees = s.num_trees;
    cfg.splitter.numerical = NumericalSplit::Histogram { bins: 16 }; // coarse quantiles
    cfg.splitter.categorical = CategoricalSplit::OneHot;
    cfg.use_hessian_gain = true;
    cfg.l2 = 10.0; // heavy regularization
    cfg.max_depth = 6;
    cfg.shrinkage = 0.1;
    cfg
}

/// Builds all 16 benchmark learners for a dataset with label `label`.
/// Order matches Figure 6's legend vocabulary.
pub fn benchmark_learners(
    label: &str,
    s: LearnerScale,
) -> Vec<(&'static str, Box<dyn Learner>)> {
    let tuned_gbt = |cfg: GbtConfig, scoring| {
        let mut t = TunerLearner::new_gbt(cfg, s.tuner_trials, scoring);
        t.seed = 0x7074;
        Box::new(t) as Box<dyn Learner>
    };
    let tuned_rf = |cfg: RandomForestConfig, scoring| {
        let mut t = TunerLearner::new_rf(cfg, s.tuner_trials, scoring);
        t.seed = 0x7075;
        Box::new(t) as Box<dyn Learner>
    };
    vec![
        (
            "YDF Autotuned (opt loss)",
            tuned_gbt(ydf_gbt_default(label, s), TunerScoring::LogLoss),
        ),
        (
            "YDF Autotuned (opt acc)",
            tuned_gbt(ydf_gbt_default(label, s), TunerScoring::Accuracy),
        ),
        (
            "LGBM Autotuned (opt loss)",
            tuned_gbt(lgbm_gbt(label, s), TunerScoring::LogLoss),
        ),
        ("YDF GBT (benchmark hp)", {
            let mut cfg = GbtConfig::benchmark_rank1(label);
            cfg.num_trees = s.num_trees;
            Box::new(GradientBoostedTreesLearner::new(cfg))
        }),
        (
            "LGBM Autotuned (opt acc)",
            tuned_gbt(lgbm_gbt(label, s), TunerScoring::Accuracy),
        ),
        (
            "SKLearn RF (default)",
            Box::new(RandomForestLearner::new(sklearn_rf(label, s))),
        ),
        ("YDF RF (benchmark hp)", {
            let mut cfg = RandomForestConfig::benchmark_rank1(label);
            cfg.num_trees = s.num_trees;
            cfg.compute_oob = false;
            Box::new(RandomForestLearner::new(cfg))
        }),
        ("SKLearn Autotuned", tuned_rf(sklearn_rf(label, s), TunerScoring::Accuracy)),
        (
            "LGBM GBT (default)",
            Box::new(GradientBoostedTreesLearner::new(lgbm_gbt(label, s))),
        ),
        (
            "YDF RF (default)",
            Box::new(RandomForestLearner::new(ydf_rf_default(label, s))),
        ),
        (
            "YDF GBT (default)",
            Box::new(GradientBoostedTreesLearner::new(ydf_gbt_default(label, s))),
        ),
        ("TF Linear (default)", {
            let mut cfg = LinearConfig::new(label);
            cfg.epochs = 30;
            Box::new(LinearLearner::new(cfg))
        }),
        (
            "XGB GBT (default)",
            Box::new(GradientBoostedTreesLearner::new(xgb_gbt(label, s))),
        ),
        ("XGB Autotuned (opt acc)", tuned_gbt(xgb_gbt(label, s), TunerScoring::Accuracy)),
        ("TF EBT (default)", Box::new(GradientBoostedTreesLearner::new(tf_ebt(label, s)))),
        (
            "XGB Autotuned (opt loss)",
            tuned_gbt(xgb_gbt(label, s), TunerScoring::LogLoss),
        ),
    ]
}

/// The 9 untuned learners of Table 2, in its row order.
pub fn untuned_learner_names() -> Vec<&'static str> {
    vec![
        "YDF GBT (benchmark hp)",
        "SKLearn RF (default)",
        "YDF RF (benchmark hp)",
        "LGBM GBT (default)",
        "YDF RF (default)",
        "YDF GBT (default)",
        "TF Linear (default)",
        "XGB GBT (default)",
        "TF EBT (default)",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;

    #[test]
    fn sixteen_learners() {
        let learners = benchmark_learners("label", LearnerScale::default());
        assert_eq!(learners.len(), 16);
        let names: Vec<&str> = learners.iter().map(|(n, _)| *n).collect();
        assert!(names.contains(&"YDF Autotuned (opt loss)"));
        assert!(names.contains(&"TF EBT (default)"));
        // Untuned names are a subset.
        for u in untuned_learner_names() {
            assert!(names.contains(&u), "{u}");
        }
    }

    #[test]
    fn each_default_learner_trains() {
        let spec = synthetic::spec_by_name("Blood_Transfusion").unwrap();
        let opts = synthetic::GenOptions { max_examples: 150, ..Default::default() };
        let ds = synthetic::generate(spec, 5, &opts);
        let scale = LearnerScale { num_trees: 3, tuner_trials: 1 };
        for (name, learner) in benchmark_learners("label", scale) {
            if name.contains("Autotuned") {
                continue; // covered by tuner tests; skip for speed
            }
            let model = learner.train(&ds).unwrap_or_else(|e| panic!("{name}: {e}"));
            let acc = crate::evaluation_free_accuracy(model.as_ref(), &ds);
            assert!(acc > 0.4, "{name}: accuracy {acc}");
        }
    }
}
