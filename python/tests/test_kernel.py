"""L1 kernel correctness: Pallas traversal vs the pointer-chasing oracle.

Hypothesis sweeps shapes and tree structures; every case asserts exact
agreement (the kernel and the oracle compute identical float32 selects).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import forest as fk
from compile.kernels.ref import forest_traverse_ref, random_forest_tensors


def run_both(features, tensors, depth):
    nf, nt, npos, nneg, lv = tensors
    got = np.asarray(
        fk.forest_traverse(features, nf, nt, npos, nneg, lv, depth=depth))
    want = forest_traverse_ref(features, nf, nt, npos, nneg, lv, depth)
    return got, want


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    num_trees=st.integers(1, 8),
    num_nodes=st.sampled_from([8, 32, 64]),
    num_features=st.integers(1, 6),
    batch=st.sampled_from([1, 4, 16]),
    depth=st.integers(1, 8),
)
def test_kernel_matches_ref(seed, num_trees, num_nodes, num_features, batch, depth):
    rng = np.random.default_rng(seed)
    tensors = random_forest_tensors(
        rng, num_trees, num_nodes, num_features, max_depth=depth)
    features = rng.normal(size=(batch, num_features)).astype(np.float32)
    got, want = run_both(features, tensors, depth)
    np.testing.assert_array_equal(got, want)


def test_full_artifact_shapes():
    """The exact shapes the AOT artifact is compiled with."""
    rng = np.random.default_rng(7)
    tensors = random_forest_tensors(
        rng, fk.MAX_TREES, fk.MAX_NODES, fk.MAX_FEATURES, max_depth=fk.MAX_DEPTH)
    features = rng.normal(size=(fk.BATCH, fk.MAX_FEATURES)).astype(np.float32)
    got, want = run_both(features, tensors, fk.MAX_DEPTH)
    np.testing.assert_array_equal(got, want)
    assert got.shape == (fk.MAX_TREES, fk.BATCH)


def test_all_leaf_trees_return_root_value():
    rng = np.random.default_rng(3)
    nf = -np.ones((4, 8), dtype=np.int32)
    nt = np.zeros((4, 8), dtype=np.float32)
    npos = np.zeros((4, 8), dtype=np.int32)
    nneg = np.zeros((4, 8), dtype=np.int32)
    lv = rng.normal(size=(4, 8)).astype(np.float32)
    features = rng.normal(size=(5, 3)).astype(np.float32)
    got = np.asarray(fk.forest_traverse(features, nf, nt, npos, nneg, lv, depth=4))
    for t in range(4):
        np.testing.assert_allclose(got[t], np.full(5, lv[t, 0]))


def test_single_stump_thresholds():
    """Hand-built stump: x0 >= 0 ? +1 : -1."""
    nf = np.array([[0, -1, -1]], dtype=np.int32)
    nt = np.zeros((1, 3), dtype=np.float32)
    npos = np.array([[1, 0, 0]], dtype=np.int32)
    nneg = np.array([[2, 0, 0]], dtype=np.int32)
    lv = np.array([[0.0, 1.0, -1.0]], dtype=np.float32)
    features = np.array([[0.5], [-0.5], [0.0]], dtype=np.float32)
    got = np.asarray(fk.forest_traverse(features, nf, nt, npos, nneg, lv, depth=2))
    np.testing.assert_allclose(got[0], [1.0, -1.0, 1.0])  # >= is positive


@pytest.mark.parametrize("depth", [1, 3, 12])
def test_depth_truncation_consistent(depth):
    """Truncated traversal must agree between kernel and oracle."""
    rng = np.random.default_rng(11)
    tensors = random_forest_tensors(rng, 3, 64, 4, max_depth=10)
    features = rng.normal(size=(8, 4)).astype(np.float32)
    got, want = run_both(features, tensors, depth)
    np.testing.assert_array_equal(got, want)
