//! Measured engine routing: per-(model, batch-size) calibration replaces
//! the static §3.7 preference order.
//!
//! The paper picks the fastest compatible engine with a fixed ranking
//! (QuickScorer → flat → naive), but no single engine wins across model
//! shape × batch size × hardware (see the database-perspective comparison
//! in PAPERS.md). This module makes the choice a measurement: at model
//! load, a micro-calibration pass times every compatible engine variant
//! (QuickScorer / flat / compiled, each in its SIMD and scalar lane) on
//! synthetic blocks shaped by the model's own dataspec, one timing per
//! batch-size bucket ([`BUCKETS`] = 1, 8, 64, 512 rows). The ranked
//! result is a [`CalibrationTable`]; for models loaded from disk it is
//! cached as a small JSON file next to the model (`<model>.router.json`,
//! versioned + checksummed like the compiled-forest artifact) so repeat
//! opens skip the measurement.
//!
//! A [`Router`] pins one engine per bucket for a session's lifetime.
//! `Session::predict_block_pooled` and the serving `Batcher` route each
//! flush by its actual row count, so a 1-row interactive request and a
//! 512-row coalesced flush can hit different engines on the same model.
//! Every candidate engine is bit-identical on the core model types
//! (pinned by `rust/tests/properties.rs`), so routing only ever changes
//! speed, never output.
//!
//! Failure policy: a corrupt, truncated or stale table falls back to the
//! static order silently (one `ydf_warn!`), never errors — the table is
//! a cache of measurements, not part of the model. Each routing decision
//! increments `ydf_router_decisions_total{engine=,bucket=}`.

use crate::dataset::{ColumnData, Dataset, FeatureSemantic, MISSING_CAT};
use crate::model::Model;
use crate::obs::Counter;
use crate::utils::json::Json;
use crate::utils::rng::Rng;
use crate::ydf_warn;
use std::path::{Path, PathBuf};
use std::time::Instant;

use super::{compiled, flat, quickscorer, InferenceEngine, BLOCK_SIZE};

/// Batch-size buckets the router calibrates and routes over: a single
/// interactive row, a small coalesced flush, one inference block, and a
/// bulk flush.
pub const BUCKETS: [usize; 4] = [1, 8, 64, 512];

/// Bucket label values used in `ydf_router_decisions_total{bucket=…}`.
const BUCKET_LABELS: [&str; 4] = ["1", "8", "64", "512"];

/// Calibration-table file format version; bump on incompatible changes
/// (an old on-disk table then falls back to the static order).
pub const TABLE_VERSION: u64 = 1;

/// Seed for the synthetic calibration rows. Fixed so the measurement
/// procedure is deterministic given a seed: the same model and seed see
/// the same calibration inputs (timings still vary with the machine —
/// that variance is exactly what the cached table freezes).
pub const DEFAULT_SEED: u64 = 0x9DF0_0C41;

/// Maps a flush's actual row count to its bucket index. Boundaries are
/// the geometric midpoints between adjacent bucket sizes, so each flush
/// is attributed to the bucket whose calibration point it is closest to
/// (in ratio terms).
pub fn bucket_index(rows: usize) -> usize {
    if rows <= 2 {
        0
    } else if rows <= 22 {
        1
    } else if rows <= 181 {
        2
    } else {
        3
    }
}

/// The engine families the router can choose between. Naive is excluded
/// on purpose: it exists as the correctness reference and never wins.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum EngineKind {
    QuickScorer,
    Flat,
    Compiled,
}

/// One routable engine configuration: a family plus which block kernel
/// (`set_simd`) it runs. The calibration table stores rankings of these.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct Variant {
    pub kind: EngineKind,
    pub simd: bool,
}

impl Variant {
    /// Stable serialization tag, e.g. `quickscorer[simd]` or
    /// `compiled[scalar]` — intentionally not the engine's display
    /// `name()`, which varies with the model kind.
    pub fn tag(&self) -> String {
        let kind = match self.kind {
            EngineKind::QuickScorer => "quickscorer",
            EngineKind::Flat => "flat",
            EngineKind::Compiled => "compiled",
        };
        let lane = if self.simd { "simd" } else { "scalar" };
        format!("{kind}[{lane}]")
    }

    pub fn parse(tag: &str) -> Option<Variant> {
        let (kind, lane) = tag.strip_suffix(']')?.split_once('[')?;
        let kind = match kind {
            "quickscorer" => EngineKind::QuickScorer,
            "flat" => EngineKind::Flat,
            "compiled" => EngineKind::Compiled,
            _ => return None,
        };
        let simd = match lane {
            "simd" => true,
            "scalar" => false,
            _ => return None,
        };
        Some(Variant { kind, simd })
    }
}

/// Compiles one variant for `model`, or `None` when the model's
/// structure rules the family out (QuickScorer's 64-leaf/condition
/// envelope, non-forest models, …).
fn build_variant(model: &dyn Model, v: Variant) -> Option<Box<dyn InferenceEngine>> {
    match v.kind {
        EngineKind::QuickScorer => quickscorer::QuickScorerEngine::compile(model).map(|mut e| {
            e.set_simd(v.simd);
            Box::new(e) as Box<dyn InferenceEngine>
        }),
        EngineKind::Flat => flat::FlatEngine::compile(model).map(|mut e| {
            e.set_simd(v.simd);
            Box::new(e) as Box<dyn InferenceEngine>
        }),
        EngineKind::Compiled => compiled::CompiledEngine::compile(model).map(|mut e| {
            e.set_simd(v.simd);
            Box::new(e) as Box<dyn InferenceEngine>
        }),
    }
}

/// Every variant worth timing for `model`. Artifact-backed
/// [`compiled::CompiledModel`]s only resolve to the compiled engine
/// (there is no tree structure to feed the others); in-memory forests
/// get every family that compiles, each in both lanes. Empty for
/// wrapper models (ensembles, calibrators) — those fall back to the
/// model's own row loop, same as before the router existed.
pub fn candidate_variants(model: &dyn Model) -> Vec<Variant> {
    let kinds: Vec<EngineKind> =
        if model.as_any().downcast_ref::<compiled::CompiledModel>().is_some() {
            vec![EngineKind::Compiled]
        } else {
            let mut kinds = Vec::new();
            if quickscorer::QuickScorerEngine::compile(model).is_some() {
                kinds.push(EngineKind::QuickScorer);
            }
            if flat::FlatEngine::compile(model).is_some() {
                kinds.push(EngineKind::Flat);
            }
            if compiled::CompiledEngine::compile(model).is_some() {
                kinds.push(EngineKind::Compiled);
            }
            kinds
        };
    kinds
        .into_iter()
        .flat_map(|kind| [Variant { kind, simd: true }, Variant { kind, simd: false }])
        .collect()
}

/// The static §3.7 preference order — what `fastest_engine` pinned
/// before calibration existed and what every fallback path routes to:
/// compiled for artifact-backed models, else QuickScorer when it
/// compiles, else the flat engine. The lane is the build default (the
/// `simd` cargo feature). `None` for wrapper models.
pub fn static_variant(model: &dyn Model) -> Option<Variant> {
    let simd = cfg!(feature = "simd");
    if model.as_any().downcast_ref::<compiled::CompiledModel>().is_some() {
        return Some(Variant { kind: EngineKind::Compiled, simd });
    }
    if quickscorer::QuickScorerEngine::compile(model).is_some() {
        Some(Variant { kind: EngineKind::QuickScorer, simd })
    } else if flat::FlatEngine::compile(model).is_some() {
        Some(Variant { kind: EngineKind::Flat, simd })
    } else {
        None
    }
}

/// Synthesizes `rows` calibration rows shaped by the model's dataspec:
/// numericals uniform over each column's observed [min, max], categorials
/// uniform over the vocabulary, plus a sprinkle of missing values so the
/// timed traversal exercises the missing-value branches real traffic
/// hits. Every spec column (label included — engines never read it, but
/// `Dataset::new` wants equal lengths) is filled.
pub fn synthetic_rows(model: &dyn Model, rows: usize, seed: u64) -> Dataset {
    let spec = model.spec();
    let mut rng = Rng::seed_from_u64(seed);
    let missing = |rng: &mut Rng| rng.bernoulli(1.0 / 16.0);
    let columns: Vec<ColumnData> = spec
        .columns
        .iter()
        .map(|col| match col.semantic {
            FeatureSemantic::Numerical => {
                let (lo, hi) = if col.num_stats.max > col.num_stats.min {
                    (col.num_stats.min, col.num_stats.max)
                } else {
                    (0.0, 1.0)
                };
                ColumnData::Numerical(
                    (0..rows)
                        .map(|_| {
                            if missing(&mut rng) {
                                f32::NAN
                            } else {
                                rng.uniform_range(lo, hi) as f32
                            }
                        })
                        .collect(),
                )
            }
            FeatureSemantic::Categorical => {
                let vocab = col.vocab_size();
                ColumnData::Categorical(
                    (0..rows)
                        .map(|_| {
                            if vocab == 0 || missing(&mut rng) {
                                MISSING_CAT
                            } else {
                                rng.uniform_usize(vocab) as u32
                            }
                        })
                        .collect(),
                )
            }
            FeatureSemantic::Boolean => ColumnData::Boolean(
                (0..rows)
                    .map(|_| {
                        if missing(&mut rng) {
                            crate::dataset::MISSING_BOOL
                        } else {
                            rng.bernoulli(0.5) as u8
                        }
                    })
                    .collect(),
            ),
            FeatureSemantic::CategoricalSet => {
                let vocab = col.vocab_size();
                let mut offsets = vec![0u32];
                let mut values = Vec::new();
                for _ in 0..rows {
                    if vocab > 0 && !missing(&mut rng) {
                        for _ in 0..rng.uniform_usize(3) {
                            values.push(rng.uniform_usize(vocab) as u32);
                        }
                    }
                    offsets.push(values.len() as u32);
                }
                ColumnData::CategoricalSet { offsets, values }
            }
        })
        .collect();
    Dataset::new(spec.clone(), columns).expect("synthetic calibration columns match the spec")
}

/// Best-of-passes ns/row for one engine on the first `rows` rows of the
/// calibration dataset. Repetitions are scaled so every bucket measures
/// a comparable number of rows; one warmup pass primes caches and lazy
/// scratch before the clock starts.
fn measure_ns_per_row(
    engine: &dyn InferenceEngine,
    ds: &Dataset,
    rows: usize,
    out: &mut [f64],
) -> f64 {
    let reps = (1024 / rows).clamp(2, 64);
    engine.predict_batch(ds, 0..rows, out);
    let mut best = f64::INFINITY;
    for _ in 0..2 {
        let start = Instant::now();
        for _ in 0..reps {
            engine.predict_batch(ds, 0..rows, out);
        }
        let ns = start.elapsed().as_nanos() as f64 / (reps * rows) as f64;
        best = best.min(ns);
    }
    best
}

/// One bucket's measured ranking, fastest first.
#[derive(Clone, Debug, PartialEq)]
pub struct BucketRanking {
    /// The bucket's calibration row count (a [`BUCKETS`] entry).
    pub rows: usize,
    /// `(variant, ns_per_row)`, sorted ascending by time.
    pub ranking: Vec<(Variant, f64)>,
}

/// The result of a micro-calibration pass: per-bucket engine rankings,
/// plus the identity of the measurement (model fingerprint + data seed)
/// so a cached table can be validated against the model it is opened
/// next to.
#[derive(Clone, Debug, PartialEq)]
pub struct CalibrationTable {
    /// `fnv1a64` of the model file's bytes; `0` for in-memory
    /// calibrations that are never persisted.
    pub model_fingerprint: u64,
    /// Seed the synthetic calibration rows were drawn with.
    pub seed: u64,
    /// One entry per [`BUCKETS`] bucket, in bucket order.
    pub buckets: Vec<BucketRanking>,
}

/// Runs the micro-calibration pass for `model`: builds every candidate
/// variant, times each per bucket on seeded synthetic rows, and returns
/// the ranked table. `None` when no optimized engine compiles (wrapper
/// models) — callers fall back to the static order / row loop. Costs a
/// few milliseconds per model; runs once per load (or never, when a
/// valid cached table exists).
pub fn measure_model(model: &dyn Model, seed: u64) -> Option<CalibrationTable> {
    let engines: Vec<(Variant, Box<dyn InferenceEngine>)> = candidate_variants(model)
        .into_iter()
        .filter_map(|v| build_variant(model, v).map(|e| (v, e)))
        .collect();
    if engines.is_empty() {
        return None;
    }
    let max_rows = *BUCKETS.last().unwrap();
    let ds = synthetic_rows(model, max_rows, seed);
    let dim = engines[0].1.output_dim();
    let mut out = vec![0.0f64; max_rows * dim];
    let buckets = BUCKETS
        .iter()
        .map(|&rows| {
            let mut ranking: Vec<(Variant, f64)> = engines
                .iter()
                .map(|(v, e)| (*v, measure_ns_per_row(e.as_ref(), &ds, rows, &mut out[..rows * dim])))
                .collect();
            ranking.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal));
            BucketRanking { rows, ranking }
        })
        .collect();
    Some(CalibrationTable { model_fingerprint: 0, seed, buckets })
}

/// Path of the cached calibration table for a model file:
/// `<model>.router.json`, next to the model so the two travel together.
pub fn table_path(model_path: &Path) -> PathBuf {
    let mut os = model_path.as_os_str().to_os_string();
    os.push(".router.json");
    PathBuf::from(os)
}

impl CalibrationTable {
    fn payload_json(&self) -> Json {
        let mut buckets = Json::obj();
        for b in &self.buckets {
            let mut bj = Json::obj();
            bj.set(
                "ranking",
                Json::Arr(b.ranking.iter().map(|(v, _)| Json::Str(v.tag())).collect()),
            )
            .set(
                "ns_per_row",
                Json::Arr(b.ranking.iter().map(|(_, ns)| Json::Num(*ns)).collect()),
            );
            buckets.set(&b.rows.to_string(), bj);
        }
        let mut payload = Json::obj();
        payload
            .set("version", Json::Num(TABLE_VERSION as f64))
            .set("model_fingerprint", Json::Str(format!("{:016x}", self.model_fingerprint)))
            .set("block_size", Json::Num(BLOCK_SIZE as f64))
            .set("seed", Json::Str(format!("{:016x}", self.seed)))
            .set("buckets", buckets);
        payload
    }

    /// Serializes to the on-disk format: a one-line header carrying the
    /// version and the `fnv1a64` checksum of every byte that follows,
    /// then the payload JSON. Hashing the exact payload bytes (like the
    /// compiled-forest artifact does) means any flipped or truncated
    /// byte is detected, whitespace included.
    pub fn to_file_string(&self) -> String {
        let payload = self.payload_json().to_string_pretty();
        let checksum = compiled::fnv1a64(payload.as_bytes());
        format!(
            "{{\"router_table_version\": {TABLE_VERSION}, \"checksum\": \"{checksum:016x}\"}}\n{payload}"
        )
    }

    /// Parses the on-disk format, verifying header, checksum and payload
    /// structure. Errors describe what failed; callers treat any error
    /// as "no table".
    pub fn from_file_string(text: &str) -> Result<CalibrationTable, String> {
        let (header, payload_text) = text
            .split_once('\n')
            .ok_or_else(|| "missing header line".to_string())?;
        let header = Json::parse(header).map_err(|e| format!("invalid header: {e}"))?;
        let version = header.req_f64("router_table_version")? as u64;
        if version != TABLE_VERSION {
            return Err(format!("table version {version} != supported {TABLE_VERSION}"));
        }
        let want = u64::from_str_radix(header.req_str("checksum")?, 16)
            .map_err(|e| format!("invalid checksum field: {e}"))?;
        let got = compiled::fnv1a64(payload_text.as_bytes());
        if got != want {
            return Err(format!("checksum mismatch: stored {want:016x}, computed {got:016x}"));
        }
        let payload = Json::parse(payload_text).map_err(|e| format!("invalid payload: {e}"))?;
        if payload.req_usize("block_size")? != BLOCK_SIZE {
            return Err("table was calibrated for a different BLOCK_SIZE".to_string());
        }
        let model_fingerprint = u64::from_str_radix(payload.req_str("model_fingerprint")?, 16)
            .map_err(|e| format!("invalid model_fingerprint: {e}"))?;
        let seed = u64::from_str_radix(payload.req_str("seed")?, 16)
            .map_err(|e| format!("invalid seed: {e}"))?;
        let bj = payload.req("buckets")?;
        let mut buckets = Vec::with_capacity(BUCKETS.len());
        for rows in BUCKETS {
            let b = bj.req(&rows.to_string())?;
            let tags = b.req_arr("ranking")?;
            let times = b.req_arr("ns_per_row")?;
            if tags.is_empty() || tags.len() != times.len() {
                return Err(format!("bucket {rows}: malformed ranking"));
            }
            let mut ranking = Vec::with_capacity(tags.len());
            for (tag, ns) in tags.iter().zip(times) {
                let tag = tag.as_str().ok_or_else(|| format!("bucket {rows}: non-string tag"))?;
                let variant = Variant::parse(tag)
                    .ok_or_else(|| format!("bucket {rows}: unknown engine variant '{tag}'"))?;
                let ns = ns.as_f64().ok_or_else(|| format!("bucket {rows}: non-numeric time"))?;
                ranking.push((variant, ns));
            }
            buckets.push(BucketRanking { rows, ranking });
        }
        Ok(CalibrationTable { model_fingerprint, seed, buckets })
    }

    pub fn save(&self, path: &Path) -> Result<(), String> {
        std::fs::write(path, self.to_file_string())
            .map_err(|e| format!("cannot write {}: {e}", path.display()))
    }

    /// Loads and validates a cached table. Any failure — unreadable
    /// file, corrupt bytes, version skew, or a fingerprint that no
    /// longer matches the model file (the model was retrained or
    /// recompiled under the table) — yields `None` with a warning;
    /// never an error, never a panic.
    pub fn load(path: &Path, expect_fingerprint: u64) -> Option<CalibrationTable> {
        let text = std::fs::read_to_string(path).ok()?;
        match CalibrationTable::from_file_string(&text) {
            Ok(table) if table.model_fingerprint == expect_fingerprint => Some(table),
            Ok(_) => {
                ydf_warn!(
                    "calibration table {} is stale (model changed); using the static engine order",
                    path.display()
                );
                None
            }
            Err(e) => {
                ydf_warn!(
                    "ignoring corrupt calibration table {}: {e}; using the static engine order",
                    path.display()
                );
                None
            }
        }
    }
}

/// How a session resolves its router when opening a model file.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum CalibrateMode {
    /// Ignore calibration entirely: pin the static §3.7 order.
    Off,
    /// Use a valid cached table; measure-and-cache when none exists.
    /// A *corrupt or stale* table falls back to the static order without
    /// re-measuring (re-calibrating behind a bad file would mask it).
    Load,
    /// Always re-measure and rewrite the cached table.
    Force,
}

impl CalibrateMode {
    pub fn parse(s: &str) -> Option<CalibrateMode> {
        match s {
            "off" => Some(CalibrateMode::Off),
            "load" => Some(CalibrateMode::Load),
            "force" => Some(CalibrateMode::Force),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            CalibrateMode::Off => "off",
            CalibrateMode::Load => "load",
            CalibrateMode::Force => "force",
        }
    }
}

/// One bucket's pinned route.
struct BucketRoute {
    /// Index into [`Router::engines`].
    engine: usize,
    variant: Variant,
    /// The engine's display `name()`, for `health` / flush labels.
    name: String,
    /// `ydf_router_decisions_total{engine=<tag>, bucket=<rows>}`.
    decisions: Counter,
}

/// The ranked routing table a `Session` pins: one compiled engine per
/// batch-size bucket (deduplicated — a variant winning several buckets
/// is compiled once). Built either from the static order (every bucket
/// routes to the same engine) or from a [`CalibrationTable`].
pub struct Router {
    engines: Vec<Box<dyn InferenceEngine>>,
    buckets: Vec<BucketRoute>,
    calibrated: bool,
}

impl Router {
    /// The pre-router behavior: the static §3.7 engine pinned for every
    /// bucket. `None` for wrapper models (callers use the model's own
    /// row loop).
    pub fn uncalibrated(model: &dyn Model) -> Option<Router> {
        let v = static_variant(model)?;
        Some(Router::from_variants(model, [v; 4], false))
    }

    /// Routes per the measured table: each bucket pins the fastest
    /// ranked variant that still compiles for this model (a stale-ish
    /// table may name a variant a retrained model no longer supports);
    /// buckets with no buildable ranked variant fall back to the static
    /// choice. `None` for wrapper models.
    pub fn calibrated(model: &dyn Model, table: &CalibrationTable) -> Option<Router> {
        let fallback = static_variant(model)?;
        let mut per_bucket = [fallback; 4];
        for (i, slot) in per_bucket.iter_mut().enumerate() {
            if let Some(v) = table.buckets.get(i).and_then(|b| {
                b.ranking.iter().map(|(v, _)| *v).find(|&v| build_variant(model, v).is_some())
            }) {
                *slot = v;
            }
        }
        Some(Router::from_variants(model, per_bucket, true))
    }

    /// Measures and routes in one step without touching the filesystem
    /// (benchmarks, tests, `Session::new_calibrated`). Falls back to the
    /// static order when nothing compiles to measure.
    pub fn calibrated_in_memory(model: &dyn Model, seed: u64) -> Option<Router> {
        match measure_model(model, seed) {
            Some(table) => Router::calibrated(model, &table),
            None => Router::uncalibrated(model),
        }
    }

    fn from_variants(model: &dyn Model, per_bucket: [Variant; 4], calibrated: bool) -> Router {
        let metrics = crate::obs::metrics();
        let mut engines: Vec<(Variant, Box<dyn InferenceEngine>)> = Vec::new();
        let buckets = per_bucket
            .iter()
            .enumerate()
            .map(|(i, &v)| {
                let engine = match engines.iter().position(|(ev, _)| *ev == v) {
                    Some(idx) => idx,
                    None => {
                        let built = build_variant(model, v)
                            .expect("router variants are checked buildable before pinning");
                        engines.push((v, built));
                        engines.len() - 1
                    }
                };
                let name = engines[engine].1.name();
                let tag = v.tag();
                let decisions = metrics.counter_with(
                    "ydf_router_decisions_total",
                    "Per-flush engine-routing decisions by the calibrated router.",
                    &[("engine", tag.as_str()), ("bucket", BUCKET_LABELS[i])],
                );
                BucketRoute { engine, variant: v, name, decisions }
            })
            .collect();
        Router {
            engines: engines.into_iter().map(|(_, e)| e).collect(),
            buckets,
            calibrated,
        }
    }

    /// The engine a `rows`-row flush routes to, recording the decision
    /// in `ydf_router_decisions_total`. This is the hot-path entry: one
    /// bucket lookup plus one relaxed counter increment.
    pub fn route(&self, rows: usize) -> &dyn InferenceEngine {
        let b = &self.buckets[bucket_index(rows)];
        b.decisions.inc();
        self.engines[b.engine].as_ref()
    }

    /// The engine `route(rows)` would pick, without recording a
    /// decision (tests, benchmarks, introspection).
    pub fn engine_for_rows(&self, rows: usize) -> &dyn InferenceEngine {
        self.engines[self.buckets[bucket_index(rows)].engine].as_ref()
    }

    pub fn engine_name_for_rows(&self, rows: usize) -> &str {
        &self.buckets[bucket_index(rows)].name
    }

    pub fn variant_for_rows(&self, rows: usize) -> Variant {
        self.buckets[bucket_index(rows)].variant
    }

    /// The name reported as *the* session engine: the route for one
    /// [`BLOCK_SIZE`] inference block, the workhorse flush size.
    pub fn primary_name(&self) -> &str {
        self.engine_name_for_rows(BLOCK_SIZE)
    }

    pub fn output_dim(&self) -> usize {
        self.engines[0].output_dim()
    }

    /// Whether the routes came from a measurement (vs the static order).
    pub fn calibrated(&self) -> bool {
        self.calibrated
    }

    /// Route summary for `health` and benches:
    /// `{"calibrated": …, "buckets": {"1": "flat[simd]", …}}`.
    pub fn to_json(&self) -> Json {
        let mut buckets = Json::obj();
        for (rows, route) in BUCKETS.iter().zip(&self.buckets) {
            buckets.set(&rows.to_string(), Json::Str(route.variant.tag()));
        }
        let mut j = Json::obj();
        j.set("calibrated", Json::Bool(self.calibrated)).set("buckets", buckets);
        j
    }

    /// Consumes the router, returning the primary (bucket-`BLOCK_SIZE`)
    /// engine — the thin-wrapper path `fastest_engine` uses.
    pub fn into_primary(mut self) -> Box<dyn InferenceEngine> {
        let idx = self.buckets[bucket_index(BLOCK_SIZE)].engine;
        self.engines.swap_remove(idx)
    }
}

/// Resolves the router for a model loaded from `path` under `mode`;
/// this is the `Session::open_with` policy in one place:
///
/// * `Off` — static order, any cached table ignored.
/// * `Load` — a valid cached table routes; a present-but-invalid one
///   (corrupt / stale) falls back to the static order; a missing one is
///   measured now and cached.
/// * `Force` — always re-measure and rewrite the cache.
///
/// Never errors: every failure path degrades to the static order (or
/// the row loop for engine-less models).
pub fn for_model_file(model: &dyn Model, path: &Path, mode: CalibrateMode) -> Option<Router> {
    if mode == CalibrateMode::Off {
        return Router::uncalibrated(model);
    }
    let fingerprint = std::fs::read(path).map(|b| compiled::fnv1a64(&b)).unwrap_or(0);
    let cache = table_path(path);
    if mode == CalibrateMode::Load && cache.exists() {
        return match CalibrationTable::load(&cache, fingerprint) {
            Some(table) => Router::calibrated(model, &table),
            None => Router::uncalibrated(model),
        };
    }
    match measure_model(model, DEFAULT_SEED) {
        Some(mut table) => {
            table.model_fingerprint = fingerprint;
            if let Err(e) = table.save(&cache) {
                ydf_warn!("cannot cache calibration table: {e}");
            }
            Router::calibrated(model, &table)
        }
        None => Router::uncalibrated(model),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learner::gbt::GbtConfig;
    use crate::learner::{GradientBoostedTreesLearner, Learner};

    fn small_gbt() -> Box<dyn Model> {
        let data = crate::dataset::synthetic::adult_like(300, 11);
        let mut config = GbtConfig::new("income");
        config.num_trees = 3;
        config.max_depth = 4;
        GradientBoostedTreesLearner::new(config).train(&data).unwrap()
    }

    #[test]
    fn bucket_index_boundaries() {
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 0);
        assert_eq!(bucket_index(3), 1);
        assert_eq!(bucket_index(8), 1);
        assert_eq!(bucket_index(22), 1);
        assert_eq!(bucket_index(23), 2);
        assert_eq!(bucket_index(64), 2);
        assert_eq!(bucket_index(181), 2);
        assert_eq!(bucket_index(182), 3);
        assert_eq!(bucket_index(512), 3);
        assert_eq!(bucket_index(100_000), 3);
    }

    #[test]
    fn variant_tags_round_trip() {
        for kind in [EngineKind::QuickScorer, EngineKind::Flat, EngineKind::Compiled] {
            for simd in [true, false] {
                let v = Variant { kind, simd };
                assert_eq!(Variant::parse(&v.tag()), Some(v), "{}", v.tag());
            }
        }
        assert_eq!(Variant::parse("naive[simd]"), None);
        assert_eq!(Variant::parse("flat[wide]"), None);
        assert_eq!(Variant::parse("flat"), None);
    }

    #[test]
    fn static_router_matches_compile_engines_head() {
        let model = small_gbt();
        let router = Router::uncalibrated(model.as_ref()).expect("GBT compiles an engine");
        let head = super::super::compile_engines(model.as_ref())
            .into_iter()
            .next()
            .unwrap();
        assert_eq!(router.primary_name(), head.name());
        assert!(!router.calibrated());
        // Every bucket routes to the same engine in the static order.
        for rows in BUCKETS {
            assert_eq!(router.engine_name_for_rows(rows), router.primary_name());
        }
    }

    #[test]
    fn table_file_round_trip_and_tamper_detection() {
        let table = CalibrationTable {
            model_fingerprint: 0xDEAD_BEEF_0BAD_F00D,
            seed: 42,
            buckets: BUCKETS
                .iter()
                .map(|&rows| BucketRanking {
                    rows,
                    ranking: vec![
                        (Variant { kind: EngineKind::Flat, simd: true }, 12.5),
                        (Variant { kind: EngineKind::QuickScorer, simd: false }, 31.25),
                    ],
                })
                .collect(),
        };
        let text = table.to_file_string();
        let back = CalibrationTable::from_file_string(&text).unwrap();
        assert_eq!(back, table);

        // Any flipped byte in the payload is caught by the checksum; a
        // flipped header is caught by its own parse/validation.
        let bytes = text.as_bytes();
        for pos in (0..bytes.len()).step_by(11) {
            let mut bad = bytes.to_vec();
            bad[pos] ^= 0x10;
            if let Ok(s) = String::from_utf8(bad) {
                assert!(
                    CalibrationTable::from_file_string(&s).is_err(),
                    "flip at byte {pos} must be rejected"
                );
            }
        }
        // Truncations are caught too.
        for cut in (0..text.len()).step_by(17) {
            assert!(CalibrationTable::from_file_string(&text[..cut]).is_err());
        }
        // Version skew falls back.
        let skewed = text.replacen(
            &format!("\"router_table_version\": {TABLE_VERSION}"),
            &format!("\"router_table_version\": {}", TABLE_VERSION + 1),
            1,
        );
        assert!(CalibrationTable::from_file_string(&skewed).is_err());
    }

    #[test]
    fn measured_router_routes_every_bucket_and_reports_json() {
        let model = small_gbt();
        let table = measure_model(model.as_ref(), DEFAULT_SEED).expect("engines compile");
        assert_eq!(table.buckets.len(), BUCKETS.len());
        for b in &table.buckets {
            assert!(!b.ranking.is_empty());
            // Ranked ascending by measured time.
            for pair in b.ranking.windows(2) {
                assert!(pair[0].1 <= pair[1].1);
            }
        }
        let router = Router::calibrated(model.as_ref(), &table).unwrap();
        assert!(router.calibrated());
        for rows in [1, 7, 64, 2000] {
            // Routing must resolve and the engine must score.
            let ds = synthetic_rows(model.as_ref(), 4, 1);
            let engine = router.engine_for_rows(rows);
            let mut out = vec![0.0; 4 * engine.output_dim()];
            engine.predict_batch(&ds, 0..4, &mut out);
        }
        let j = router.to_json();
        assert_eq!(j.get("calibrated"), Some(&Json::Bool(true)));
        for rows in BUCKETS {
            let tag = j.req("buckets").unwrap().req_str(&rows.to_string()).unwrap().to_string();
            assert!(Variant::parse(&tag).is_some(), "{tag}");
        }
        // Decisions feed the global metrics registry.
        router.route(1);
        router.route(512);
        let prom = crate::obs::prom::render_global();
        assert!(prom.contains("ydf_router_decisions_total"), "{prom}");
    }

    #[test]
    fn synthetic_rows_are_deterministic_given_a_seed() {
        let model = small_gbt();
        let a = synthetic_rows(model.as_ref(), 64, 7);
        let b = synthetic_rows(model.as_ref(), 64, 7);
        let c = synthetic_rows(model.as_ref(), 64, 8);
        let row_key = |ds: &Dataset| format!("{:?}", ds.row(63));
        assert_eq!(row_key(&a), row_key(&b));
        assert_ne!(row_key(&a), row_key(&c));
    }
}
