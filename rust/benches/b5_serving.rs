//! b5: serving-runtime benchmark — the micro-batching path under load.
//!
//! Six families of configurations, all closed-loop (one in-flight
//! request per client — the standard closed-system load model), all
//! recorded to `BENCH_serving.json` so serving performance is tracked
//! across PRs exactly like `BENCH_inference.json` tracks the engine
//! kernels. Every combo now also records client-observed **p99 latency**
//! — the control-plane work (hot reload, admission control) is judged on
//! tail behavior, not means:
//!
//! * `s{rows}_c{clients}` — the PR-3 grid: request-size × concurrency
//!   over one model, single-threaded flush scoring.
//! * `trace_off_s8_c4` / `trace_on_s8_c4` — tracing overhead: the same
//!   closed loop with the Chrome-trace collector disabled vs enabled.
//!   The off combo must stay within noise of `s8_c4` — disabled span
//!   sites cost one relaxed atomic load and no allocation.
//! * `m2_s{rows}_c{clients}` — multi-model: two sessions behind one
//!   registry, clients alternating models, each model coalescing only
//!   its own rows.
//! * `par_s512_c4` / `seq_s512_c4` — large-flush: 512-row requests whose
//!   coalesced flushes fan block spans out across the scoring pool
//!   (`par`, 4 workers) vs the single-threaded baseline (`seq`), so the
//!   parallel-flush speedup is tracked across PRs.
//! * `reload_s8_c4` — hot reload under load: clients hammer one model
//!   name while it is swapped repeatedly; the p99 shows what a swap
//!   costs the tail (clients re-resolve on generation change and retry
//!   requests lost to a draining batcher — the loop never drops one).
//! * `quota_s8_c16` — admission saturation: more offered load than the
//!   per-model quota and shared admission budget admit; rejected
//!   submissions spin-retry, so the numbers describe the accepted
//!   goodput and its tail latency under sustained overload.
//! * `routed_s8_c4` / `routed_s512_c4` — measured routing: the same
//!   closed loops as `s8_c4` / `seq_s512_c4` through a
//!   calibration-routed session (`Session::new_calibrated`), whose
//!   batcher re-routes every flush to the per-batch-size winner engine
//!   instead of the static order — the routed-vs-static serving rows.
//! * `route_s8_c4` / `route_s8_c4_faildown` — fleet routing: the 8-row ×
//!   4-client closed loop over real loopback TCP through a `ydf route`
//!   front end backed by two replica backends (vs the in-process `s8_c4`
//!   numbers, this row carries the full wire + routing-tier overhead),
//!   then the same loop after one replica is shut down — the p99 with
//!   every request failing over to the surviving replica.
//!
//! Run: cargo bench --bench b5_serving
//!      cargo bench --bench b5_serving -- --requests=500 --out=path.json

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};
use ydf::dataset::synthetic;
use ydf::learner::gbt::GbtConfig;
use ydf::learner::{GradientBoostedTreesLearner, Learner};
use ydf::serving::{Batcher, BatcherConfig, Registry, RowBlock, Session};
use ydf::utils::json::Json;

const REQUEST_ROWS: [usize; 3] = [1, 8, 64];
const CONCURRENCY: [usize; 3] = [1, 4, 16];

struct ComboResult {
    key: String,
    models: usize,
    score_threads: usize,
    request_rows: usize,
    concurrency: usize,
    requests: usize,
    us_per_request: f64,
    p99_us: f64,
    requests_per_s: f64,
    rows_per_s: f64,
    mean_batch_rows: f64,
}

fn train_session(seed: u64, trees: usize) -> Session {
    let ds = synthetic::adult_like(4000, seed);
    let mut cfg = GbtConfig::new("income");
    cfg.num_trees = trees;
    cfg.max_depth = 5;
    Session::new(GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap())
}

/// As [`train_session`], but with the in-memory micro-calibration pass:
/// the session's router times every engine variant per batch-size
/// bucket and each flush runs the measured winner for its row count.
fn train_calibrated_session(seed: u64, trees: usize) -> Session {
    let ds = synthetic::adult_like(4000, seed);
    let mut cfg = GbtConfig::new("income");
    cfg.num_trees = trees;
    cfg.max_depth = 5;
    Session::new_calibrated(GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap())
}

/// A quick-to-train replacement model for the reload combo: the swap
/// cadence must be dominated by the swap, not by training the stand-in.
fn train_small_session(seed: u64) -> Session {
    let ds = synthetic::adult_like(1000, seed);
    let mut cfg = GbtConfig::new("income");
    cfg.num_trees = 10;
    cfg.max_depth = 3;
    Session::new(GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap())
}

/// p99 of `xs` (microseconds); sorts in place.
fn p99(xs: &mut [f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((xs.len() as f64 * 0.99).ceil() as usize).clamp(1, xs.len());
    xs[rank - 1]
}

/// Closed loop over per-client (batcher, prototype-request) lanes — one
/// lane per client, so coalesced batches mix genuinely different rows
/// (a shared prototype would give every flush identical tree paths and
/// flatter-than-real numbers). Client `i` drives lane `i`,
/// `requests_per_client` times. Returns (wall seconds, p99 µs).
fn run_closed_loop(lanes: &[(Arc<Batcher>, RowBlock)], requests_per_client: usize) -> (f64, f64) {
    let t0 = Instant::now();
    let per_client: Vec<Vec<f64>> = std::thread::scope(|s| {
        let handles: Vec<_> = lanes
            .iter()
            .map(|(batcher, block)| {
                s.spawn(move || {
                    let mut us = Vec::with_capacity(requests_per_client);
                    for _ in 0..requests_per_client {
                        let r0 = Instant::now();
                        let out = batcher
                            .submit(block)
                            .expect("bench load stays under queue capacity")
                            .wait()
                            .expect("batcher serves until dropped");
                        us.push(r0.elapsed().as_secs_f64() * 1e6);
                        std::hint::black_box(out);
                    }
                    us
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let wall = t0.elapsed().as_secs_f64();
    let mut all: Vec<f64> = per_client.into_iter().flatten().collect();
    (wall, p99(&mut all))
}

#[allow(clippy::too_many_arguments)]
fn combo_result(
    key: String,
    models: usize,
    score_threads: usize,
    request_rows: usize,
    concurrency: usize,
    requests_per_client: usize,
    wall: f64,
    p99_us: f64,
    batches: u64,
    batched_rows: u64,
) -> ComboResult {
    let total_requests = requests_per_client * concurrency;
    ComboResult {
        key,
        models,
        score_threads,
        request_rows,
        concurrency,
        requests: total_requests,
        us_per_request: wall / total_requests as f64 * 1e6,
        p99_us,
        requests_per_s: total_requests as f64 / wall,
        rows_per_s: (total_requests * request_rows) as f64 / wall,
        mean_batch_rows: if batches > 0 { batched_rows as f64 / batches as f64 } else { 0.0 },
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut requests_per_client = 200usize;
    let mut out_path = "BENCH_serving.json".to_string();
    for a in &args {
        if let Some(v) = a.strip_prefix("--requests=") {
            requests_per_client = v.parse().unwrap();
        } else if let Some(v) = a.strip_prefix("--out=") {
            out_path = v.to_string();
        }
    }

    // The b4 workload: adult-like mixed features, QuickScorer-compatible
    // GBT, so b4 and b5 numbers describe the same model family.
    let session = Arc::new(train_session(20230806, 50));
    println!(
        "serving benchmark: engine {}, {} requests/client\n  {:>16} {:>12} {:>11} {:>14} {:>12} {:>14} {:>12} {:>16}",
        session.engine_name(),
        requests_per_client,
        "combo",
        "request_rows",
        "concurrency",
        "us/request",
        "p99_us",
        "requests/s",
        "rows/s",
        "mean batch rows",
    );
    let mut results: Vec<ComboResult> = Vec::new();
    let report = |r: &ComboResult| {
        println!(
            "  {:>16} {:>12} {:>11} {:>14.2} {:>12.0} {:>14.0} {:>12.0} {:>16.1}",
            r.key,
            r.request_rows,
            r.concurrency,
            r.us_per_request,
            r.p99_us,
            r.requests_per_s,
            r.rows_per_s,
            r.mean_batch_rows,
        );
    };

    // Family 1: the single-model request-size × concurrency grid
    // (single-threaded flushes — the PR-3 baseline numbers).
    for &request_rows in &REQUEST_ROWS {
        for &concurrency in &CONCURRENCY {
            let batcher = Arc::new(Batcher::new(
                Arc::clone(&session),
                BatcherConfig {
                    // Adaptive drain: coalesce exactly the backlog that
                    // accumulates while the previous batch scores.
                    max_delay: Duration::ZERO,
                    score_threads: 1,
                    ..Default::default()
                },
            ));
            let lanes: Vec<(Arc<Batcher>, RowBlock)> = (0..concurrency)
                .map(|client| {
                    (Arc::clone(&batcher), request_block(&session, request_rows, client))
                })
                .collect();
            let (wall, tail) = run_closed_loop(&lanes, requests_per_client);
            let snap = batcher.stats().snapshot();
            let r = combo_result(
                format!("s{request_rows}_c{concurrency}"),
                1,
                1,
                request_rows,
                concurrency,
                requests_per_client,
                wall,
                tail,
                snap.batches,
                snap.batched_rows,
            );
            report(&r);
            results.push(r);
        }
    }

    // Family 1b: tracing overhead — the same 8-row × 4-client closed
    // loop with the Chrome-trace collector off and then on. The off
    // combo pins the disabled-path cost (one relaxed atomic load per
    // span site, no allocation): its us/request must stay within noise
    // of `s8_c4` above. The on combo bounds the enabled-path cost.
    for (key, trace_on) in [("trace_off_s8_c4", false), ("trace_on_s8_c4", true)] {
        let batcher = Arc::new(Batcher::new(
            Arc::clone(&session),
            BatcherConfig {
                max_delay: Duration::ZERO,
                score_threads: 1,
                ..Default::default()
            },
        ));
        let lanes: Vec<(Arc<Batcher>, RowBlock)> = (0..4)
            .map(|client| (Arc::clone(&batcher), request_block(&session, 8, client)))
            .collect();
        if trace_on {
            ydf::obs::trace::enable();
        }
        let (wall, tail) = run_closed_loop(&lanes, requests_per_client);
        if trace_on {
            ydf::obs::trace::disable();
            // Drain the buffer so the collected spans don't linger for
            // the rest of the process; the events themselves are not
            // the artifact here, the throughput delta is.
            std::hint::black_box(ydf::obs::trace::take_json());
        }
        let snap = batcher.stats().snapshot();
        let r = combo_result(
            key.to_string(),
            1,
            1,
            8,
            4,
            requests_per_client,
            wall,
            tail,
            snap.batches,
            snap.batched_rows,
        );
        report(&r);
        results.push(r);
    }

    // Family 2: two models behind one registry, clients alternating —
    // the multi-model serving dimension.
    {
        let registry = Registry::new(BatcherConfig {
            max_delay: Duration::ZERO,
            score_threads: 1,
            ..Default::default()
        });
        registry.register("m0", train_session(20230806, 50)).unwrap();
        registry.register("m1", train_session(7151, 50)).unwrap();
        for &concurrency in &[4usize, 16] {
            let request_rows = 8usize;
            // One lane per client, alternating models, rows varied per
            // client.
            let entries = registry.entries();
            let lanes: Vec<(Arc<Batcher>, RowBlock)> = (0..concurrency)
                .map(|client| {
                    let e = &entries[client % entries.len()];
                    (Arc::clone(e.batcher()), request_block(e.session(), request_rows, client))
                })
                .collect();
            // The registry's stats persist across concurrency runs;
            // report this run's delta.
            let base: Vec<(u64, u64)> = entries
                .iter()
                .map(|e| {
                    let s = e.stats().snapshot();
                    (s.batches, s.batched_rows)
                })
                .collect();
            let (wall, tail) = run_closed_loop(&lanes, requests_per_client);
            let (mut batches, mut batched_rows) = (0u64, 0u64);
            for (e, (b0, r0)) in entries.iter().zip(&base) {
                let s = e.stats().snapshot();
                batches += s.batches - b0;
                batched_rows += s.batched_rows - r0;
            }
            let r = combo_result(
                format!("m2_s{request_rows}_c{concurrency}"),
                2,
                1,
                request_rows,
                concurrency,
                requests_per_client,
                wall,
                tail,
                batches,
                batched_rows,
            );
            report(&r);
            results.push(r);
        }
    }

    // Family 3: large coalesced flushes, parallel-scored vs serial —
    // the `predict_into`-style fan-out inside a flush.
    for (key, score_threads) in [("seq_s512_c4", 1usize), ("par_s512_c4", 4usize)] {
        let batcher = Arc::new(Batcher::new(
            Arc::clone(&session),
            BatcherConfig {
                max_delay: Duration::ZERO,
                score_threads,
                max_queue_rows: 8 * 512,
                ..Default::default()
            },
        ));
        let lanes: Vec<(Arc<Batcher>, RowBlock)> = (0..4)
            .map(|client| (Arc::clone(&batcher), request_block(&session, 512, client)))
            .collect();
        // Fewer, heavier requests: same row volume as ~64-row combos.
        let heavy_requests = (requests_per_client / 8).max(10);
        let (wall, tail) = run_closed_loop(&lanes, heavy_requests);
        let snap = batcher.stats().snapshot();
        let r = combo_result(
            key.to_string(),
            1,
            score_threads,
            512,
            4,
            heavy_requests,
            wall,
            tail,
            snap.batches,
            snap.batched_rows,
        );
        report(&r);
        results.push(r);
    }

    // Family 4: hot reload under load — the control-plane cost combo.
    // Four clients hammer one model name while it is swapped three
    // times; every request eventually completes (a submission lost to a
    // draining generation re-resolves and retries), and the p99 records
    // what the swaps cost the tail.
    {
        let registry = Arc::new(Registry::new(BatcherConfig {
            max_delay: Duration::ZERO,
            score_threads: 1,
            ..Default::default()
        }));
        registry.register("hot", train_session(20230806, 50)).unwrap();
        let (concurrency, request_rows) = (4usize, 8usize);
        let clients_done = AtomicUsize::new(0);
        let retried = AtomicUsize::new(0);
        let t0 = Instant::now();
        let per_client: Vec<Vec<f64>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..concurrency)
                .map(|client| {
                    let registry = Arc::clone(&registry);
                    let (clients_done, retried) = (&clients_done, &retried);
                    s.spawn(move || {
                        let mut us = Vec::with_capacity(requests_per_client);
                        let mut entry = registry.resolve(Some("hot")).unwrap();
                        let mut block = request_block(entry.session(), request_rows, client);
                        for _ in 0..requests_per_client {
                            let r0 = Instant::now();
                            loop {
                                let live = registry.resolve(Some("hot")).unwrap();
                                if live.generation() != entry.generation() {
                                    // Swapped: rebuild the request for the
                                    // new generation's dataspec scratch.
                                    block =
                                        request_block(live.session(), request_rows, client);
                                    entry = live;
                                }
                                match entry.batcher().submit(&block) {
                                    Ok(p) => {
                                        if let Ok(out) = p.wait() {
                                            std::hint::black_box(out);
                                            break;
                                        }
                                        // Drained out from under us —
                                        // retry against the new generation.
                                        retried.fetch_add(1, Ordering::Relaxed);
                                    }
                                    Err(_) => {
                                        retried.fetch_add(1, Ordering::Relaxed);
                                        std::thread::yield_now();
                                    }
                                }
                            }
                            us.push(r0.elapsed().as_secs_f64() * 1e6);
                        }
                        clients_done.fetch_add(1, Ordering::Relaxed);
                        us
                    })
                })
                .collect();
            // The swapper: three hot swaps spaced across the run.
            let swapper_registry = Arc::clone(&registry);
            let clients_done = &clients_done;
            s.spawn(move || {
                for round in 0..3u64 {
                    std::thread::sleep(Duration::from_millis(40));
                    if clients_done.load(Ordering::Relaxed) == concurrency {
                        break; // load finished before the swap schedule did
                    }
                    let incoming = train_small_session(9000 + round);
                    swapper_registry.swap("hot", incoming).expect("swap of a live model");
                }
            });
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let wall = t0.elapsed().as_secs_f64();
        let mut all: Vec<f64> = per_client.into_iter().flatten().collect();
        let tail = p99(&mut all);
        let hot = registry.resolve(Some("hot")).unwrap();
        let snap = hot.stats().snapshot(); // stats survive swaps with the name
        println!(
            "  (reload combo: {} reloads, {} retried submissions)",
            snap.reloads,
            retried.load(Ordering::Relaxed)
        );
        let r = combo_result(
            "reload_s8_c4".to_string(),
            1,
            1,
            request_rows,
            concurrency,
            requests_per_client,
            wall,
            tail,
            snap.batches,
            snap.batched_rows,
        );
        report(&r);
        results.push(r);
    }

    // Family 5: admission saturation — offered load far above the quota
    // and shared admission budget; rejected submissions spin-retry, so
    // this measures accepted goodput and its tail under overload.
    {
        let registry = Registry::new(BatcherConfig {
            max_delay: Duration::ZERO,
            score_threads: 1,
            quota_rows: 64,
            admission_rows: 96,
            ..Default::default()
        });
        registry.register("quota", train_session(20230806, 50)).unwrap();
        let entry = registry.resolve(Some("quota")).unwrap();
        let (concurrency, request_rows) = (16usize, 8usize);
        // Shorter per-client run: 16 clients spin-retrying is heavy.
        let saturated_requests = (requests_per_client / 2).max(20);
        let t0 = Instant::now();
        let per_client: Vec<Vec<f64>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..concurrency)
                .map(|client| {
                    let entry = &entry;
                    s.spawn(move || {
                        let block = request_block(entry.session(), request_rows, client);
                        let mut us = Vec::with_capacity(saturated_requests);
                        for _ in 0..saturated_requests {
                            let r0 = Instant::now();
                            let out = loop {
                                match entry.batcher().submit(&block) {
                                    Ok(p) => {
                                        break p.wait().expect("batcher serves until dropped")
                                    }
                                    Err(_) => std::thread::yield_now(), // quota/admission bounce
                                }
                            };
                            us.push(r0.elapsed().as_secs_f64() * 1e6);
                            std::hint::black_box(out);
                        }
                        us
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        let wall = t0.elapsed().as_secs_f64();
        let mut all: Vec<f64> = per_client.into_iter().flatten().collect();
        let tail = p99(&mut all);
        let snap = entry.stats().snapshot();
        println!("  (quota combo: {} rejected submissions)", snap.rejected);
        let r = combo_result(
            "quota_s8_c16".to_string(),
            1,
            1,
            request_rows,
            concurrency,
            saturated_requests,
            wall,
            tail,
            snap.batches,
            snap.batched_rows,
        );
        report(&r);
        results.push(r);
    }

    // Family 6: measured routing — the s8_c4 and seq_s512_c4 loops
    // through a calibrated session, so the routed rows sit next to
    // their static-order baselines in the same report. The calibrated
    // router re-routes each flush by its actual row count; routing only
    // ever changes which bit-identical engine runs.
    let routed = Arc::new(train_calibrated_session(20230806, 50));
    println!(
        "  (routed combos: calibration pins {} @8 rows, {} @512 rows)",
        routed.engine_name_for_rows(8),
        routed.engine_name_for_rows(512),
    );
    for (key, request_rows, per_client) in [
        ("routed_s8_c4", 8usize, requests_per_client),
        ("routed_s512_c4", 512usize, (requests_per_client / 8).max(10)),
    ] {
        let batcher = Arc::new(Batcher::new(
            Arc::clone(&routed),
            BatcherConfig {
                max_delay: Duration::ZERO,
                score_threads: 1,
                max_queue_rows: 8 * 512,
                ..Default::default()
            },
        ));
        let lanes: Vec<(Arc<Batcher>, RowBlock)> = (0..4)
            .map(|client| (Arc::clone(&batcher), request_block(&routed, request_rows, client)))
            .collect();
        let (wall, tail) = run_closed_loop(&lanes, per_client);
        let snap = batcher.stats().snapshot();
        let r = combo_result(
            key.to_string(),
            1,
            1,
            request_rows,
            4,
            per_client,
            wall,
            tail,
            snap.batches,
            snap.batched_rows,
        );
        report(&r);
        results.push(r);
    }

    // Family 7: fleet routing over loopback TCP — two replica backends
    // behind one `ydf route` front end. Unlike every family above, this
    // loop pays the real wire cost (TCP round trip, JSON decode on the
    // backend) plus the routing hop, so it is compared against its own
    // faildown row, not against the in-process combos. The faildown row
    // re-runs the identical loop after one replica is shut down: every
    // request placed on the dead replica fails over to the survivor.
    {
        use std::io::{BufRead, BufReader, Write};
        use std::net::{TcpListener, TcpStream};

        let free_addr = || {
            let probe = TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = probe.local_addr().unwrap();
            drop(probe);
            addr
        };
        let backend_addrs = [free_addr(), free_addr()];
        let registries: Vec<Arc<Registry>> = backend_addrs
            .iter()
            .map(|addr| {
                let registry = Arc::new(Registry::new(BatcherConfig {
                    max_delay: Duration::ZERO,
                    score_threads: 1,
                    ..Default::default()
                }));
                registry.register("m", train_session(20230806, 50)).unwrap();
                let config = ydf::serving::ServerConfig {
                    addr: addr.to_string(),
                    workers: 8,
                    ..Default::default()
                };
                let shared = Arc::clone(&registry);
                std::thread::spawn(move || ydf::serving::serve_shared(shared, &config));
                registry
            })
            .collect();
        let router_addr = free_addr();
        {
            let config = ydf::serving::RouteConfig {
                addr: router_addr.to_string(),
                workers: 8,
                backends: backend_addrs.iter().map(|a| a.to_string()).collect(),
                probe_interval: Duration::from_millis(100),
                backoff_base_ms: 1,
                backoff_cap_ms: 20,
                ..Default::default()
            };
            std::thread::spawn(move || ydf::serving::route(&config));
        }
        let connect = |addr: std::net::SocketAddr| -> (BufReader<TcpStream>, TcpStream) {
            let deadline = Instant::now() + Duration::from_secs(10);
            loop {
                match TcpStream::connect(addr) {
                    Ok(s) => return (BufReader::new(s.try_clone().unwrap()), s),
                    Err(e) => {
                        assert!(Instant::now() < deadline, "no server at {addr}: {e}");
                        std::thread::sleep(Duration::from_millis(20));
                    }
                }
            }
        };
        // Wait for both backends, then the router.
        for &addr in &backend_addrs {
            let (mut r, mut w) = connect(addr);
            writeln!(w, r#"{{"cmd": "health"}}"#).unwrap();
            w.flush().unwrap();
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
        }
        let request_json = |rows: usize, lane: usize| -> String {
            let workclasses = ["Private", "Self-emp-inc", "Federal-gov", "Local-gov"];
            let educations = ["HS-grad", "Bachelors", "Masters", "Doctorate"];
            let body: Vec<String> = (0..rows)
                .map(|i| {
                    let k = lane * 31 + i;
                    format!(
                        r#"{{"age": {}, "hours_per_week": {}, "workclass": "{}", "education": "{}", "capital_gain": {}}}"#,
                        18 + k % 60,
                        20 + (k * 7) % 50,
                        workclasses[k % workclasses.len()],
                        educations[(k / 2) % educations.len()],
                        (k % 9) * 700,
                    )
                })
                .collect();
            format!(r#"{{"model": "m", "rows": [{}]}}"#, body.join(", "))
        };
        // Closed TCP loop: 4 clients, one in-flight request each;
        // retryable sheds retry (they count toward the request's wall
        // time, exactly what a well-behaved client would experience).
        let (concurrency, request_rows) = (4usize, 8usize);
        let run_tcp_loop = |per_client: usize| -> (f64, f64, usize) {
            let t0 = Instant::now();
            let outcome: Vec<(Vec<f64>, usize)> = std::thread::scope(|s| {
                let handles: Vec<_> = (0..concurrency)
                    .map(|client| {
                        let request_json = &request_json;
                        let connect = &connect;
                        s.spawn(move || {
                            let (mut reader, mut writer) = connect(router_addr);
                            let line = request_json(request_rows, client);
                            let mut us = Vec::with_capacity(per_client);
                            let mut retried = 0usize;
                            for _ in 0..per_client {
                                let r0 = Instant::now();
                                loop {
                                    writeln!(writer, "{line}").unwrap();
                                    writer.flush().unwrap();
                                    let mut resp = String::new();
                                    assert!(
                                        reader.read_line(&mut resp).unwrap() > 0,
                                        "router dropped a request"
                                    );
                                    let j = Json::parse(resp.trim()).unwrap();
                                    if j.get("error").is_none() {
                                        std::hint::black_box(resp);
                                        break;
                                    }
                                    assert_eq!(
                                        j.get("retryable"),
                                        Some(&Json::Bool(true)),
                                        "only retryable errors are acceptable: {resp}"
                                    );
                                    retried += 1;
                                    std::thread::sleep(Duration::from_millis(1));
                                }
                                us.push(r0.elapsed().as_secs_f64() * 1e6);
                            }
                            (us, retried)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            let wall = t0.elapsed().as_secs_f64();
            let mut all: Vec<f64> = Vec::new();
            let mut retried = 0usize;
            for (us, r) in outcome {
                all.extend(us);
                retried += r;
            }
            (wall, p99(&mut all), retried)
        };
        let tcp_requests = (requests_per_client / 2).max(20);
        let batch_totals = |registries: &[Arc<Registry>]| -> (u64, u64) {
            registries.iter().fold((0, 0), |(b, rws), reg| {
                let s = reg.resolve(Some("m")).unwrap().stats().snapshot();
                (b + s.batches, rws + s.batched_rows)
            })
        };
        let (b0, r0) = batch_totals(&registries);
        let (wall, tail, _) = run_tcp_loop(tcp_requests);
        let (b1, r1) = batch_totals(&registries);
        let r = combo_result(
            "route_s8_c4".to_string(),
            1,
            1,
            request_rows,
            concurrency,
            tcp_requests,
            wall,
            tail,
            b1 - b0,
            r1 - r0,
        );
        report(&r);
        results.push(r);

        // Shut down replica 0 directly, wait until the router's probes
        // mark it Down, then run the identical loop degraded.
        {
            let (mut reader, mut writer) = connect(backend_addrs[0]);
            writeln!(writer, r#"{{"cmd": "shutdown"}}"#).unwrap();
            writer.flush().unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
        }
        let (mut router_reader, mut router_writer) = connect(router_addr);
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            writeln!(router_writer, r#"{{"cmd": "health"}}"#).unwrap();
            router_writer.flush().unwrap();
            let mut line = String::new();
            router_reader.read_line(&mut line).unwrap();
            if line.contains("\"Down\"") {
                break;
            }
            assert!(Instant::now() < deadline, "router never marked the killed replica Down");
            std::thread::sleep(Duration::from_millis(25));
        }
        let (b0, r0) = batch_totals(&registries[1..]);
        let (wall, tail, retried) = run_tcp_loop(tcp_requests);
        let (b1, r1) = batch_totals(&registries[1..]);
        println!("  (faildown combo: {retried} retried requests)");
        let r = combo_result(
            "route_s8_c4_faildown".to_string(),
            1,
            1,
            request_rows,
            concurrency,
            tcp_requests,
            wall,
            tail,
            b1 - b0,
            r1 - r0,
        );
        report(&r);
        results.push(r);

        // Stop the router and the surviving backend in-band.
        writeln!(router_writer, r#"{{"cmd": "shutdown"}}"#).unwrap();
        router_writer.flush().unwrap();
        let mut line = String::new();
        router_reader.read_line(&mut line).unwrap();
        let (mut reader, mut writer) = connect(backend_addrs[1]);
        writeln!(writer, r#"{{"cmd": "shutdown"}}"#).unwrap();
        writer.flush().unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
    }

    let mut combos = Json::obj();
    for r in &results {
        let mut cj = Json::obj();
        cj.set("models", Json::Num(r.models as f64))
            .set("score_threads", Json::Num(r.score_threads as f64))
            .set("request_rows", Json::Num(r.request_rows as f64))
            .set("concurrency", Json::Num(r.concurrency as f64))
            .set("requests", Json::Num(r.requests as f64))
            .set("us_per_request", Json::Num(r.us_per_request))
            .set("p99_us", Json::Num(r.p99_us))
            .set("requests_per_s", Json::Num(r.requests_per_s))
            .set("rows_per_s", Json::Num(r.rows_per_s))
            .set("mean_batch_rows", Json::Num(r.mean_batch_rows));
        combos.set(&r.key, cj);
    }
    let mut j = Json::obj();
    j.set("engine", Json::Str(session.engine_name()))
        .set("router", routed.router_json())
        .set("requests_per_client", Json::Num(requests_per_client as f64))
        .set("block_size", Json::Num(ydf::inference::BLOCK_SIZE as f64))
        .set("combos", combos);
    match std::fs::write(&out_path, j.to_string_pretty()) {
        Ok(()) => eprintln!("wrote {out_path}"),
        Err(e) => eprintln!("cannot write {out_path}: {e}"),
    }
}

/// Builds one request of `rows` rows from dataset-like feature values,
/// varied per lane so coalesced batches are not degenerate.
fn request_block(session: &Session, rows: usize, lane: usize) -> RowBlock {
    let workclasses = ["Private", "Self-emp-inc", "Federal-gov", "Local-gov"];
    let educations = ["HS-grad", "Bachelors", "Masters", "Doctorate"];
    let mut block = session.new_block();
    for i in 0..rows {
        let k = lane * 31 + i;
        let row = Json::parse(&format!(
            r#"{{"age": {}, "hours_per_week": {}, "workclass": "{}",
                "education": "{}", "capital_gain": {}}}"#,
            18 + k % 60,
            20 + (k * 7) % 50,
            workclasses[k % workclasses.len()],
            educations[(k / 2) % educations.len()],
            (k % 9) * 700,
        ))
        .unwrap();
        session.decode_row(&mut block, &row).unwrap();
    }
    block
}
