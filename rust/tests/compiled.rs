//! Differential test harness for the compiled-forest engine
//! (`rust/src/inference/compiled.rs`): randomized compiled-vs-naive
//! bit-identity across semantics/tasks/lanes, artifact round-trips
//! through real files (mmap path), hostile-input rejection, and serving
//! integration — `.bin`-backed sessions bit-identical to JSON-backed
//! ones, including a hot swap to an artifact-backed generation under
//! concurrent load.

mod common;

use common::{adult_gbt, adult_json_rows, decode_all, mixed_ds_opt, mixed_gbt};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;
use ydf::dataset::synthetic;
use ydf::inference::compiled::{CompiledEngine, CompiledForest, CompiledModel};
use ydf::inference::naive::NaiveEngine;
use ydf::inference::InferenceEngine;
use ydf::learner::gbt::GbtConfig;
use ydf::learner::random_forest::RandomForestConfig;
use ydf::learner::{GradientBoostedTreesLearner, Learner, RandomForestLearner};
use ydf::model::io::{load_model, save_model};
use ydf::model::{Model, Task};
use ydf::serving::{BatcherConfig, Registry, Session, SubmitError};
use ydf::utils::prop::run_cases;

/// Bitwise f64 comparison: `assert_eq!` on floats would accept -0.0 vs
/// 0.0 and reject NaN vs NaN; the differential contract is exact bits.
fn assert_bits_eq(got: &[f64], want: &[f64], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            g.to_bits() == w.to_bits(),
            "{what}: value {i} differs: {g} (bits {:#x}) vs {w} (bits {:#x})",
            g.to_bits(),
            w.to_bits()
        );
    }
}

/// Fresh per-test scratch directory under the system temp dir.
fn scratch_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ydf_compiled_{name}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The headline differential property: for randomized forests over
/// mixed-semantic datasets (NaN numericals, missing categoricals and
/// booleans, out-of-dictionary categories, optional categorical-set
/// columns, oblique splits, binary/multiclass/regression, row counts
/// that leave unaligned 64-row block tails), the compiled engine is
/// bit-for-bit identical to the naive pointer-chasing engine — in both
/// the SIMD lane kernel and the scalar sweep, over full batches,
/// unaligned sub-ranges, the threaded `predict_into` fan-out, and the
/// single-row serving path.
#[test]
fn prop_compiled_engine_matches_naive() {
    run_cases(0xC0DEC, 12, |rng, case| {
        // classes: 2 → binary, 3 → multiclass, 0 → regression.
        let classes = [2usize, 3, 0][case % 3];
        let with_catset = case % 2 == 0;
        // 48..128 rows: below, straddling and above one 64-row block.
        let n = 48 + rng.uniform_usize(80);
        let ds = mixed_ds_opt(n, classes, with_catset, rng);
        let model: Box<dyn Model> = match (classes, case % 4) {
            (0, c) if c % 2 == 0 => {
                // Random Forest regression (RfRegression aggregate).
                let mut cfg = RandomForestConfig::new("label");
                cfg.task = Task::Regression;
                cfg.num_trees = 3;
                cfg.compute_oob = false;
                RandomForestLearner::new(cfg).train(&ds).unwrap()
            }
            (0, _) => {
                // GBT regression (squared-error loss, identity link).
                let mut cfg = GbtConfig::new("label");
                cfg.task = Task::Regression;
                cfg.num_trees = 3;
                cfg.max_depth = 4;
                GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap()
            }
            (_, 1) => {
                // Oblique splits (Appendix C.1 rank-1 recipe).
                let mut cfg = GbtConfig::benchmark_rank1("label");
                cfg.num_trees = 3;
                GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap()
            }
            (_, 3) => {
                let mut cfg = RandomForestConfig::new("label");
                cfg.num_trees = 3;
                cfg.compute_oob = false;
                RandomForestLearner::new(cfg).train(&ds).unwrap()
            }
            _ => {
                let mut cfg = GbtConfig::new("label");
                cfg.num_trees = 3;
                cfg.max_depth = 4;
                GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap()
            }
        };

        let naive = NaiveEngine::compile(model.as_ref());
        let mut compiled = CompiledEngine::compile(model.as_ref())
            .expect("RF/GBT models always lower to the compiled engine");
        let dim = naive.output_dim();
        assert_eq!(compiled.output_dim(), dim, "case {case}: output_dim");

        let mut want = vec![0.0f64; n * dim];
        naive.predict_batch(&ds, 0..n, &mut want);

        for simd in [true, false] {
            compiled.set_simd(simd);
            let lane = if simd { "simd" } else { "scalar" };

            let mut got = vec![0.0f64; n * dim];
            compiled.predict_batch(&ds, 0..n, &mut got);
            assert_bits_eq(&got, &want, &format!("case {case} [{lane}] full batch"));

            // Unaligned sub-range: starts and ends off block boundaries.
            let lo = 1 + rng.uniform_usize(n / 3);
            let hi = n - rng.uniform_usize(n / 4).min(n - lo - 1);
            let mut sub = vec![0.0f64; (hi - lo) * dim];
            compiled.predict_batch(&ds, lo..hi, &mut sub);
            assert_bits_eq(
                &sub,
                &want[lo * dim..hi * dim],
                &format!("case {case} [{lane}] sub-range {lo}..{hi}"),
            );

            // Threaded fan-out must tile blocks without seams.
            let mut threaded = vec![0.0f64; n * dim];
            compiled.predict_into(&ds, 3, &mut threaded);
            assert_bits_eq(&threaded, &want, &format!("case {case} [{lane}] predict_into"));

            // Single-row serving path.
            for r in [0, n / 2, n - 1] {
                let obs = ds.row(r);
                assert_bits_eq(
                    &compiled.predict_row(&obs),
                    &naive.predict_row(&obs),
                    &format!("case {case} [{lane}] predict_row {r}"),
                );
            }
        }
    });
}

/// Compile → write `.bin` → reopen (mmap where available) → the loaded
/// forest predicts bit-identically to the in-memory one and re-serializes
/// to the exact same bytes.
#[test]
fn artifact_file_roundtrip_bit_identical() {
    let (model, ds) = mixed_gbt(220, 3, 0xA7);
    let forest = CompiledForest::lower(model.as_ref()).unwrap();
    let dir = scratch_dir("roundtrip");
    let path = dir.join("model.bin");
    forest.write_artifact(&path).unwrap();

    let loaded = CompiledForest::open(&path).unwrap();
    #[cfg(all(unix, target_endian = "little"))]
    assert!(loaded.is_mapped(), "unix little-endian load should mmap");
    assert_eq!(loaded.num_trees(), forest.num_trees());
    assert_eq!(loaded.num_nodes(), forest.num_nodes());
    assert_eq!(loaded.to_artifact_bytes(), std::fs::read(&path).unwrap(), "byte-stable");

    let n = ds.num_rows();
    let mem = CompiledEngine::new(Arc::new(forest));
    let map = CompiledEngine::new(Arc::new(loaded));
    let dim = mem.output_dim();
    let mut want = vec![0.0f64; n * dim];
    let mut got = vec![0.0f64; n * dim];
    mem.predict_batch(&ds, 0..n, &mut want);
    map.predict_batch(&ds, 0..n, &mut got);
    assert_bits_eq(&got, &want, "mmap-loaded vs in-memory");

    // And the whole chain stays pinned to the naive reference.
    let mut naive_out = vec![0.0f64; n * dim];
    NaiveEngine::compile(model.as_ref()).predict_batch(&ds, 0..n, &mut naive_out);
    assert_bits_eq(&got, &naive_out, "mmap-loaded vs naive");

    std::fs::remove_file(&path).ok();
}

/// `load_model` sniffs the artifact magic: a `.bin` path yields a
/// `CompiledModel` whose metadata (features, classes, task) matches the
/// original and whose row predictions stay pinned to the naive engine.
#[test]
fn load_model_accepts_artifacts() {
    let ds = synthetic::adult_like(300, 9);
    let model = adult_gbt(300, 9, 4, 4);
    let dir = scratch_dir("load_model");
    let bin = dir.join("model.bin");
    CompiledForest::lower(model.as_ref()).unwrap().write_artifact(&bin).unwrap();

    let opened = load_model(&bin).unwrap();
    assert_eq!(opened.model_type(), "COMPILED_GRADIENT_BOOSTED_TREES");
    assert_eq!(opened.task(), model.task());
    assert_eq!(opened.input_features(), model.input_features());
    assert_eq!(opened.num_classes(), model.num_classes());
    assert_eq!(opened.label_col(), model.label_col());

    let naive = NaiveEngine::compile(model.as_ref());
    for r in [0usize, 7, 131, 299] {
        let obs = ds.row(r);
        assert_bits_eq(
            &opened.predict_row(&obs),
            &naive.predict_row(&obs),
            &format!("artifact model predict_row {r}"),
        );
    }
    std::fs::remove_file(&bin).ok();
}

/// Hostile inputs: every truncation and every single-bit corruption of a
/// valid artifact must be rejected with a clean error — no panic, no
/// out-of-bounds access. The checksum covers everything after the
/// header, and the header fields are each validated, so a flip anywhere
/// is detectable.
#[test]
fn hostile_artifacts_rejected_not_panicked() {
    let (model, _ds) = mixed_gbt(160, 2, 0x51);
    let bytes = CompiledForest::lower(model.as_ref()).unwrap().to_artifact_bytes();
    assert!(CompiledForest::from_artifact_bytes(&bytes).is_ok(), "baseline must load");

    // Truncations stepped across the file plus the header boundaries.
    let mut lengths: Vec<usize> = (0..bytes.len()).step_by(13).collect();
    lengths.extend([0, 1, 4, 12, 23, 24, bytes.len() - 1]);
    for len in lengths {
        let r = CompiledForest::from_artifact_bytes(&bytes[..len]);
        assert!(r.is_err(), "truncation to {len} bytes must be rejected");
    }

    // Single-bit flips stepped across the whole file — header, meta,
    // padding, payload, checksum field itself.
    for pos in (0..bytes.len()).step_by(7) {
        let mut c = bytes.clone();
        c[pos] ^= 0x10;
        let r = CompiledForest::from_artifact_bytes(&c);
        assert!(r.is_err(), "bit flip at byte {pos} must be rejected");
    }

    // Trailing garbage changes the exact-length expectation.
    let mut long = bytes.clone();
    long.push(0);
    assert!(CompiledForest::from_artifact_bytes(&long).is_err(), "oversize rejected");

    // Files that were never artifacts.
    let dir = scratch_dir("hostile");
    let garbage = dir.join("garbage.bin");
    std::fs::write(&garbage, b"definitely not a forest").unwrap();
    assert!(CompiledModel::open(&garbage).is_err(), "garbage file rejected");
    let jsonish = dir.join("model.json");
    std::fs::write(&jsonish, "{\"format_version\": 1}").unwrap();
    assert!(CompiledModel::open(&jsonish).is_err(), "JSON file rejected by artifact loader");

    // A truncated file behind `load_model`: the magic still sniffs as an
    // artifact, and the artifact loader reports the corruption.
    let truncated = dir.join("truncated.bin");
    std::fs::write(&truncated, &bytes[..bytes.len() / 2]).unwrap();
    let err = load_model(&truncated).expect_err("truncated artifact must not load");
    assert!(
        err.contains("truncated") || err.contains("corrupted"),
        "error should name the corruption: {err}"
    );
    for p in [&garbage, &jsonish, &truncated] {
        std::fs::remove_file(p).ok();
    }
}

/// Serving parity: a session opened from a `.bin` artifact answers the
/// exact same bits as a session opened from the JSON model it was
/// compiled from — for a QuickScorer-eligible model and for an oblique
/// model that forces the flat engine on the JSON side.
#[test]
fn artifact_session_bit_identical_to_json_session() {
    let dir = scratch_dir("session_parity");
    let rows = adult_json_rows(80);
    let train = synthetic::adult_like(300, 21);

    let plain = adult_gbt(300, 21, 5, 4);
    let oblique: Box<dyn Model> = {
        let mut cfg = GbtConfig::benchmark_rank1("income");
        cfg.num_trees = 4;
        GradientBoostedTreesLearner::new(cfg).train(&train).unwrap()
    };

    for (tag, model) in [("plain", &plain), ("oblique", &oblique)] {
        let json = dir.join(format!("{tag}.json"));
        let bin = dir.join(format!("{tag}.bin"));
        save_model(model.as_ref(), &json).unwrap();
        CompiledForest::lower(model.as_ref()).unwrap().write_artifact(&bin).unwrap();

        let js = Session::open(&json).unwrap();
        let bs = Session::open(&bin).unwrap();
        assert!(
            bs.engine_name().contains("Compiled"),
            "{tag}: artifact session engine is {}",
            bs.engine_name()
        );
        assert_eq!(js.output_dim(), bs.output_dim(), "{tag}: output_dim");
        assert_eq!(js.class_names(), bs.class_names(), "{tag}: class_names");

        let mut jb = decode_all(&js, &rows);
        let mut bb = decode_all(&bs, &rows);
        assert_bits_eq(
            &bs.predict_block(&mut bb),
            &js.predict_block(&mut jb),
            &format!("{tag}: artifact session vs JSON session"),
        );
        std::fs::remove_file(&json).ok();
        std::fs::remove_file(&bin).ok();
    }
}

/// Hot swap to an artifact-backed generation under concurrent load:
/// every request the batcher accepts is answered (the PR-6 zero-drop
/// contract), a submit racing the swap sees a clean Shutdown rejection
/// and re-resolves, and after the swap the name serves the compiled
/// engine with bits matching the offline `.bin` reference.
#[test]
fn swap_to_artifact_backed_generation_zero_drops() {
    let dir = scratch_dir("swap");
    let rows = adult_json_rows(48);

    // Incoming model, compiled to an artifact on disk.
    let incoming = adult_gbt(300, 81, 5, 4);
    let bin = dir.join("incoming.bin");
    CompiledForest::lower(incoming.as_ref()).unwrap().write_artifact(&bin).unwrap();

    // Offline reference through an artifact-backed session.
    let offline = Session::open(&bin).unwrap();
    let reference = {
        let mut block = decode_all(&offline, &rows);
        offline.predict_block(&mut block)
    };
    let dim = offline.output_dim();

    let registry = Arc::new(Registry::new(BatcherConfig {
        max_delay: Duration::from_micros(200),
        ..Default::default()
    }));
    registry.register("live", common::adult_session_owned(300, 71, 6, 4)).unwrap();

    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    std::thread::scope(|scope| {
        for client in 0..2usize {
            let registry = Arc::clone(&registry);
            let (rows, stop) = (&rows, Arc::clone(&stop));
            scope.spawn(move || {
                let mut req = 0usize;
                while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                    let start = (client * 11 + req * 5) % (rows.len() - 8);
                    let entry = registry.resolve(Some("live")).unwrap();
                    let block = decode_all(entry.session(), &rows[start..start + 8]);
                    match entry.batcher().submit(&block) {
                        Ok(pending) => {
                            let out = pending.wait().expect("accepted requests are never dropped");
                            assert_eq!(out.len(), 8 * entry.session().output_dim());
                            req += 1;
                        }
                        Err(SubmitError::Shutdown) => continue, // swapped out: re-resolve
                        Err(e) => panic!("unexpected rejection: {e}"),
                    }
                }
            });
        }
        // Swap the live name to the artifact-backed session mid-traffic.
        std::thread::sleep(Duration::from_millis(30));
        let generation = registry.swap("live", Session::open(&bin).unwrap()).unwrap();
        assert!(generation > 0);
        std::thread::sleep(Duration::from_millis(30));
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
    });

    // The surviving generation is the compiled artifact, bit-identical
    // to the offline reference.
    let entry = registry.resolve(Some("live")).unwrap();
    assert_eq!(entry.state(), ydf::serving::Lifecycle::Serving);
    assert!(
        entry.session().engine_name().contains("Compiled"),
        "post-swap engine is {}",
        entry.session().engine_name()
    );
    let block = decode_all(entry.session(), &rows);
    let out = entry.batcher().submit(&block).unwrap().wait().unwrap();
    assert_bits_eq(&out, &reference, "post-swap responses vs offline .bin reference");
    assert_eq!(out.len(), rows.len() * dim);
    assert_eq!(registry.stats_json().req_f64("reloads").unwrap(), 1.0);

    std::fs::remove_file(&bin).ok();
}
