//! Pairwise model comparison with statistical tests (§2.2: "model
//! comparison should include the results of appropriate statistical
//! tests"). Produces the wins/losses cells of Table 3.

use crate::utils::stats::sign_test_p_value;

/// Outcome of comparing learner A against learner B over many paired
/// observations (dataset × fold accuracies in the benchmark).
#[derive(Clone, Debug, Default)]
pub struct PairwiseComparison {
    pub wins: f64,
    pub losses: f64,
    pub ties: u64,
    pub mean_difference: f64,
    pub num_pairs: u64,
}

impl PairwiseComparison {
    /// Compares paired metric values (higher = better). Ties count as half
    /// a win and half a loss, as in Table 3's caption.
    pub fn from_paired(a: &[f64], b: &[f64]) -> PairwiseComparison {
        assert_eq!(a.len(), b.len());
        let mut c = PairwiseComparison::default();
        let mut diff_sum = 0.0;
        for (&x, &y) in a.iter().zip(b) {
            diff_sum += x - y;
            if (x - y).abs() < 1e-12 {
                c.ties += 1;
                c.wins += 0.5;
                c.losses += 0.5;
            } else if x > y {
                c.wins += 1.0;
            } else {
                c.losses += 1.0;
            }
        }
        c.num_pairs = a.len() as u64;
        c.mean_difference = if a.is_empty() { 0.0 } else { diff_sum / a.len() as f64 };
        c
    }

    /// Two-sided sign-test p-value on the non-tied pairs.
    pub fn p_value(&self) -> f64 {
        sign_test_p_value(
            (self.wins - self.ties as f64 * 0.5).round() as u64,
            (self.losses - self.ties as f64 * 0.5).round() as u64,
        )
    }

    /// True when A wins more than half the comparisons (the green cells of
    /// Table 3).
    pub fn a_is_better(&self) -> bool {
        self.wins > self.losses
    }

    /// Table 3 cell format: "wins/losses" (half-wins from ties rounded
    /// half-away-from-zero, as in the paper's integer cells).
    pub fn cell(&self) -> String {
        format!("{}/{}", self.wins.round() as i64, self.losses.round() as i64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_wins_losses_ties() {
        let a = vec![0.9, 0.8, 0.7, 0.6];
        let b = vec![0.8, 0.8, 0.8, 0.5];
        let c = PairwiseComparison::from_paired(&a, &b);
        assert_eq!(c.wins, 2.5);
        assert_eq!(c.losses, 1.5);
        assert_eq!(c.ties, 1);
        assert!(c.a_is_better());
        assert!((c.mean_difference - 0.025).abs() < 1e-12);
        assert_eq!(c.cell(), "3/2");
    }

    #[test]
    fn p_value_behaviour() {
        let a: Vec<f64> = (0..100).map(|i| 1.0 + i as f64).collect();
        let b: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let c = PairwiseComparison::from_paired(&a, &b);
        assert!(c.p_value() < 1e-20);
        let even_a: Vec<f64> = (0..100).map(|i| (i % 2) as f64).collect();
        let even_b: Vec<f64> = (0..100).map(|i| ((i + 1) % 2) as f64).collect();
        let c2 = PairwiseComparison::from_paired(&even_a, &even_b);
        assert!(c2.p_value() > 0.9);
    }
}
