//! TCP front end: newline-delimited JSON over `std::net`, fanned out to a
//! `utils/pool.rs` worker pool, scored through the shared [`Batcher`].
//!
//! ## Wire protocol (one JSON value per line, both directions)
//!
//! Prediction requests:
//!
//! ```text
//! {"rows": [{"age": 44, "education": "Masters"}, {"age": 23}]}
//! {"age": 44, "education": "Masters"}            // single-row shorthand
//! ```
//!
//! → `{"predictions": [[0.21, 0.79], …]}` — one array of
//! `output_dim()` values per request row, in request order. Absent or
//! `null` features are missing; unknown feature names are an error.
//!
//! Commands:
//!
//! ```text
//! {"cmd": "health"}    -> {"ok": true, "engine": …, "model_type": …}
//! {"cmd": "spec"}      -> {"features": […], "label": …, "classes": […]}
//! {"cmd": "stats"}     -> serving counters + latency percentiles
//! {"cmd": "shutdown"}  -> {"ok": true}, then the server stops accepting
//! ```
//!
//! Every error — malformed JSON, unknown feature, full queue — is a
//! `{"error": "…"}` response on the same line; the connection survives.
//! See `docs/serving.md` ("Server loop") for the full contract.

use super::batcher::{Batcher, BatcherConfig};
use super::session::Session;
use super::stats::ServingStats;
use crate::utils::json::Json;
use crate::utils::pool::WorkerPool;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Front-end configuration. `workers` bounds concurrent connections (a
/// connection occupies its worker until the peer disconnects).
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Bind address; port 0 picks an ephemeral port (printed on stdout).
    pub addr: String,
    pub workers: usize,
    pub batcher: BatcherConfig,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:8123".to_string(),
            workers: 4,
            batcher: BatcherConfig::default(),
        }
    }
}

/// Live-connection registry: a clone of every open stream, so shutdown
/// can close them and unblock workers parked in `reader.lines()` —
/// without it, one idle client connection would stall `serve()`'s worker
/// join forever.
#[derive(Default)]
struct ConnRegistry {
    streams: Mutex<HashMap<u64, TcpStream>>,
    next_id: AtomicU64,
}

impl ConnRegistry {
    fn insert(&self, stream: TcpStream) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.streams.lock().expect("registry poisoned").insert(id, stream);
        id
    }

    fn remove(&self, id: u64) {
        self.streams.lock().expect("registry poisoned").remove(&id);
    }

    fn close_all(&self) {
        for (_, s) in self.streams.lock().expect("registry poisoned").drain() {
            // Read half only: unblocks workers parked in `reader.lines()`
            // (they see EOF) while letting responses to already-accepted
            // in-flight requests still be written before the worker exits.
            let _ = s.shutdown(Shutdown::Read);
        }
    }
}

/// Binds, prints `listening on <addr>` on stdout (machine-parsable — the
/// smoke test reads the ephemeral port from it), and serves until a
/// `{"cmd": "shutdown"}` request arrives. On shutdown every open
/// connection is closed (idle clients cannot stall the exit), the
/// batcher drains, and the call returns once every worker has exited.
pub fn serve(session: Session, config: &ServerConfig) -> Result<(), String> {
    let listener = TcpListener::bind(&config.addr)
        .map_err(|e| format!("cannot bind {}: {e}", config.addr))?;
    let local = listener
        .local_addr()
        .map_err(|e| format!("cannot resolve bound address: {e}"))?;
    let session = Arc::new(session);
    let stats = Arc::new(ServingStats::new());
    let batcher = Arc::new(Batcher::with_stats(
        Arc::clone(&session),
        config.batcher.clone(),
        Arc::clone(&stats),
    ));
    println!("serving model through engine: {}", session.engine_name());
    println!("listening on {local}");
    let shutdown = Arc::new(AtomicBool::new(false));
    let registry = Arc::new(ConnRegistry::default());
    let pool = WorkerPool::new(config.workers.max(1));
    // Connections go to the least-loaded worker (a connection occupies
    // its worker until the peer disconnects, so blind round-robin could
    // queue a new connection behind a long-lived one while other workers
    // sit idle).
    let loads: Arc<Vec<AtomicUsize>> =
        Arc::new((0..pool.num_workers()).map(|_| AtomicUsize::new(0)).collect());
    for stream in listener.incoming() {
        if shutdown.load(Ordering::SeqCst) {
            break; // the wake-up connection from the shutdown handler
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        let id = stream.try_clone().ok().map(|c| registry.insert(c));
        let conn = Connection {
            session: Arc::clone(&session),
            batcher: Arc::clone(&batcher),
            stats: Arc::clone(&stats),
            shutdown: Arc::clone(&shutdown),
            wake_addr: local,
        };
        let w = loads
            .iter()
            .enumerate()
            .min_by_key(|(_, l)| l.load(Ordering::Relaxed))
            .map(|(i, _)| i)
            .unwrap_or(0);
        loads[w].fetch_add(1, Ordering::Relaxed);
        let registry = Arc::clone(&registry);
        let loads = Arc::clone(&loads);
        pool.submit_to(w, move || {
            conn.handle(stream);
            if let Some(id) = id {
                registry.remove(id);
            }
            loads[w].fetch_sub(1, Ordering::Relaxed);
        });
    }
    registry.close_all(); // unblock workers parked on idle connections
    drop(pool); // join workers (in-flight requests finish)
    drop(batcher); // flush + join the scorer
    println!("server stopped");
    Ok(())
}

struct Connection {
    session: Arc<Session>,
    batcher: Arc<Batcher>,
    stats: Arc<ServingStats>,
    shutdown: Arc<AtomicBool>,
    wake_addr: std::net::SocketAddr,
}

impl Connection {
    fn handle(&self, stream: TcpStream) {
        let mut writer = match stream.try_clone() {
            Ok(w) => w,
            Err(_) => return,
        };
        let reader = BufReader::new(stream);
        let mut block = self.session.new_block();
        for line in reader.lines() {
            let line = match line {
                Ok(l) => l,
                Err(_) => return, // peer went away
            };
            if line.trim().is_empty() {
                continue;
            }
            let (response, stop) = self.respond(&line, &mut block);
            if writeln!(writer, "{response}").and_then(|_| writer.flush()).is_err() {
                return;
            }
            if stop {
                // Shutdown acknowledged: stop accepting, then wake the
                // accept loop with a throwaway connection.
                self.shutdown.store(true, Ordering::SeqCst);
                let _ = TcpStream::connect(self.wake_addr);
                return;
            }
        }
    }

    /// One request line → (response line, stop-serving flag).
    fn respond(&self, line: &str, block: &mut super::session::RowBlock) -> (Json, bool) {
        let t0 = Instant::now();
        let request = match Json::parse(line) {
            Ok(j) => j,
            Err(e) => return (self.error(format!("invalid JSON: {e}")), false),
        };
        // Dispatch precedence (docs/serving.md): "cmd"-as-string is a
        // command, "rows"-as-array is a batch request. A model feature
        // that happens to be named "cmd" or "rows" is still reachable —
        // through the canonical {"rows": […]} form, or (for "cmd") via a
        // multi-key shorthand object — the names are only reserved at the
        // top level of the shorthand.
        if let Some(cmd) = request.get("cmd").and_then(|c| c.as_str()) {
            let sole_key = matches!(&request, Json::Obj(m) if m.len() == 1);
            if sole_key || !self.session.has_column("cmd") {
                return self.command(cmd);
            }
        }
        let rows: Vec<&Json> = match request.get("rows") {
            Some(Json::Arr(items)) => items.iter().collect(),
            Some(other) if !self.session.has_column("rows") => {
                return (
                    self.error(format!(
                        "\"rows\" must be an array of feature objects, got {other}"
                    )),
                    false,
                )
            }
            // Single-row shorthand: the object itself is the row (also the
            // path for a non-array "rows" value when the model really has
            // a feature of that name).
            _ => vec![&request],
        };
        if rows.is_empty() {
            return (self.error("request contains no rows".to_string()), false);
        }
        block.clear();
        for row in rows {
            if let Err(e) = self.session.decode_row(block, row) {
                return (self.error(e), false);
            }
        }
        let n = block.rows();
        let pending = match self.batcher.submit(block) {
            Ok(p) => p,
            // QueueFull is additionally counted in the `rejected` counter
            // by the batcher; every error response increments `errors`.
            Err(e) => return (self.error(e.to_string()), false),
        };
        let flat = match pending.wait() {
            Ok(f) => f,
            Err(e) => return (self.error(e), false),
        };
        let dim = self.session.output_dim();
        let predictions = Json::Arr(
            flat.chunks(dim)
                .map(|row| Json::Arr(row.iter().map(|&p| Json::Num(p)).collect()))
                .collect(),
        );
        let mut resp = Json::obj();
        resp.set("predictions", predictions);
        self.stats.note_request(n, t0.elapsed().as_secs_f64() * 1e6);
        (resp, false)
    }

    fn command(&self, cmd: &str) -> (Json, bool) {
        match cmd {
            "health" => {
                let mut j = Json::obj();
                j.set("ok", Json::Bool(true))
                    .set("engine", Json::Str(self.session.engine_name()))
                    .set(
                        "model_type",
                        Json::Str(self.session.model().model_type().to_string()),
                    )
                    .set("output_dim", Json::Num(self.session.output_dim() as f64));
                (j, false)
            }
            "spec" => (self.session.spec_json(), false),
            "stats" => (self.stats.to_json(), false),
            "shutdown" => {
                let mut j = Json::obj();
                j.set("ok", Json::Bool(true));
                (j, true)
            }
            other => (
                self.error(format!(
                    "unknown command '{other}' (known: health, spec, stats, shutdown)"
                )),
                false,
            ),
        }
    }

    fn error(&self, message: String) -> Json {
        self.stats.note_error();
        let mut j = Json::obj();
        j.set("error", Json::Str(message));
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::learner::gbt::GbtConfig;
    use crate::learner::{GradientBoostedTreesLearner, Learner};

    fn test_session() -> Session {
        let ds = synthetic::adult_like(200, 7);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 3;
        cfg.max_depth = 3;
        Session::new(GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap())
    }

    fn conn(session: Arc<Session>, batcher: Arc<Batcher>, stats: Arc<ServingStats>) -> Connection {
        Connection {
            session,
            batcher,
            stats,
            shutdown: Arc::new(AtomicBool::new(false)),
            wake_addr: "127.0.0.1:1".parse().unwrap(),
        }
    }

    #[test]
    fn respond_handles_requests_commands_and_errors() {
        let session = Arc::new(test_session());
        let stats = Arc::new(ServingStats::new());
        let batcher = Arc::new(Batcher::with_stats(
            Arc::clone(&session),
            BatcherConfig { max_delay: std::time::Duration::ZERO, ..Default::default() },
            Arc::clone(&stats),
        ));
        let c = conn(Arc::clone(&session), batcher, Arc::clone(&stats));
        let mut block = session.new_block();

        // Multi-row request.
        let (resp, stop) =
            c.respond(r#"{"rows": [{"age": 30}, {"age": 60, "education": "Doctorate"}]}"#, &mut block);
        assert!(!stop);
        assert_eq!(resp.req_arr("predictions").unwrap().len(), 2);

        // Single-row shorthand.
        let (resp, _) = c.respond(r#"{"age": 41}"#, &mut block);
        assert_eq!(resp.req_arr("predictions").unwrap().len(), 1);

        // Malformed JSON and unknown features answer with errors, in-band.
        let (resp, _) = c.respond("not json at all", &mut block);
        assert!(resp.req_str("error").unwrap().contains("invalid JSON"));
        let (resp, _) = c.respond(r#"{"bogus_feature": 1}"#, &mut block);
        assert!(resp.req_str("error").unwrap().contains("bogus_feature"));
        let (resp, _) = c.respond(r#"{"rows": []}"#, &mut block);
        assert!(resp.req_str("error").unwrap().contains("no rows"));
        let (resp, _) = c.respond(r#"{"rows": 5}"#, &mut block);
        assert!(resp.req_str("error").unwrap().contains("array"));

        // Commands.
        let (resp, _) = c.respond(r#"{"cmd": "health"}"#, &mut block);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        let (resp, _) = c.respond(r#"{"cmd": "spec"}"#, &mut block);
        assert_eq!(resp.req_str("label").unwrap(), "income");
        let (resp, _) = c.respond(r#"{"cmd": "stats"}"#, &mut block);
        assert!(resp.req_f64("requests").unwrap() >= 2.0);
        let (resp, _) = c.respond(r#"{"cmd": "dance"}"#, &mut block);
        assert!(resp.req_str("error").unwrap().contains("unknown command"));
        let (resp, stop) = c.respond(r#"{"cmd": "shutdown"}"#, &mut block);
        assert!(stop);
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));

        let snap = stats.snapshot();
        assert_eq!(snap.requests, 2);
        assert_eq!(snap.rows, 3);
        assert_eq!(snap.errors, 5);
    }
}
