//! Cross-validation (§5.2's 10-fold protocol and the self-evaluation
//! backend of meta-learners, §3.6).

use super::{evaluate_model, Evaluation};
use crate::dataset::Dataset;
use crate::learner::Learner;

/// Result of a K-fold cross-validation of one learner on one dataset.
#[derive(Clone, Debug)]
pub struct CrossValidation {
    pub fold_evaluations: Vec<Evaluation>,
    /// Wall-clock seconds spent training, per fold.
    pub train_seconds: Vec<f64>,
    /// Wall-clock seconds spent predicting the test fold, per fold.
    pub inference_seconds: Vec<f64>,
}

impl CrossValidation {
    pub fn mean_accuracy(&self) -> f64 {
        let accs: Vec<f64> = self.fold_evaluations.iter().map(|e| e.accuracy).collect();
        crate::utils::stats::mean(&accs)
    }

    pub fn mean_log_loss(&self) -> f64 {
        let lls: Vec<f64> = self.fold_evaluations.iter().map(|e| e.log_loss).collect();
        crate::utils::stats::mean(&lls)
    }

    pub fn mean_train_seconds(&self) -> f64 {
        crate::utils::stats::mean(&self.train_seconds)
    }

    pub fn mean_inference_seconds(&self) -> f64 {
        crate::utils::stats::mean(&self.inference_seconds)
    }
}

/// Runs K-fold cross-validation. Fold splits depend only on `seed` so they
/// are identical across learners (§5.2: "fold splits are consistent across
/// learners to facilitate a fair comparison").
pub fn cross_validate(
    learner: &dyn Learner,
    ds: &Dataset,
    folds: usize,
    seed: u64,
) -> Result<CrossValidation, String> {
    if folds < 2 {
        return Err("cross-validation requires at least 2 folds.".to_string());
    }
    let fold_rows = ds.kfold_indices(folds, seed);
    let mut fold_evaluations = Vec::with_capacity(folds);
    let mut train_seconds = Vec::with_capacity(folds);
    let mut inference_seconds = Vec::with_capacity(folds);
    for test_fold in 0..folds {
        let mut train_rows = Vec::new();
        for (f, rows) in fold_rows.iter().enumerate() {
            if f != test_fold {
                train_rows.extend_from_slice(rows);
            }
        }
        let train_ds = ds.subset(&train_rows);
        let test_ds = ds.subset(&fold_rows[test_fold]);
        let t0 = std::time::Instant::now();
        let model = learner.train(&train_ds)?;
        train_seconds.push(t0.elapsed().as_secs_f64());
        // Fold prediction rides the batch path: evaluate_model compiles
        // the fastest compatible engine and scores the fold through
        // predict_flat, so `inference_seconds` reflects engine batch
        // throughput, not the per-row Observation path.
        let t1 = std::time::Instant::now();
        let ev = evaluate_model(model.as_ref(), &test_ds, learner.label())?;
        inference_seconds.push(t1.elapsed().as_secs_f64());
        fold_evaluations.push(ev);
    }
    Ok(CrossValidation { fold_evaluations, train_seconds, inference_seconds })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::learner::gbt::GbtConfig;
    use crate::learner::GradientBoostedTreesLearner;

    #[test]
    fn cv_runs_and_aggregates() {
        let ds = synthetic::adult_like(300, 71);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 8;
        cfg.max_depth = 3;
        let learner = GradientBoostedTreesLearner::new(cfg);
        let cv = cross_validate(&learner, &ds, 3, 17).unwrap();
        assert_eq!(cv.fold_evaluations.len(), 3);
        let acc = cv.mean_accuracy();
        assert!(acc > 0.6 && acc <= 1.0, "cv accuracy {acc}");
        assert!(cv.mean_train_seconds() > 0.0);
    }

    #[test]
    fn folds_identical_across_learners() {
        let ds = synthetic::adult_like(100, 73);
        let a = ds.kfold_indices(5, 42);
        let b = ds.kfold_indices(5, 42);
        assert_eq!(a, b);
    }

    #[test]
    fn too_few_folds_rejected() {
        let ds = synthetic::adult_like(50, 74);
        let learner = GradientBoostedTreesLearner::default_config("income");
        assert!(cross_validate(&learner, &ds, 1, 1).is_err());
    }
}
