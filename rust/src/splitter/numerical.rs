//! Numerical feature splitters: exact in-sorting, exact pre-sorted, the
//! per-node automatic choice between them, and approximate histogram
//! splitting (§3.8, §2.3).

use super::score::{Labels, ScoreAcc};
use super::{
    collect_numerical, scan_sorted_pairs, NumericalSplit, SplitCandidate, SplitterConfig,
    TrainingCache,
};
use crate::dataset::Dataset;
use crate::model::tree::Condition;

/// Dispatches to the configured numerical splitter.
pub fn split_numerical(
    ds: &Dataset,
    col: usize,
    rows: &[u32],
    labels: &Labels,
    cfg: &SplitterConfig,
    cache: &mut TrainingCache,
) -> Option<SplitCandidate> {
    match cfg.numerical {
        NumericalSplit::ExactInSort => split_insort(ds, col, rows, labels, cfg),
        NumericalSplit::Presorted => split_presorted(ds, col, rows, labels, cfg, cache),
        NumericalSplit::Auto => {
            // In-sorting costs n·log n on node size n; pre-sorting costs a
            // full pass over all N rows. Pick the cheaper one per node —
            // the dynamic-choice behaviour §2.3 attributes to modularity.
            let n = rows.len() as f64;
            if n * n.log2().max(1.0) <= cache.num_rows as f64 {
                split_insort(ds, col, rows, labels, cfg)
            } else {
                split_presorted(ds, col, rows, labels, cfg, cache)
            }
        }
        NumericalSplit::Histogram { bins } => {
            split_histogram(ds, col, rows, labels, cfg, cache, bins)
        }
    }
}

/// Exact splitter, in-sorting approach: sort the node's feature values.
pub fn split_insort(
    ds: &Dataset,
    col: usize,
    rows: &[u32],
    labels: &Labels,
    cfg: &SplitterConfig,
) -> Option<SplitCandidate> {
    let (mut pairs, missing) = collect_numerical(ds, col, rows);
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    scan_sorted_pairs(&pairs, &missing, labels, cfg.min_examples).map(|r| SplitCandidate {
        condition: Condition::Higher { attr: col, threshold: r.threshold },
        gain: r.gain,
        missing_to_positive: r.missing_to_positive,
    })
}

/// Exact splitter, pre-sorting approach: reuse the global sort order of the
/// column and filter it down to the node's rows.
pub fn split_presorted(
    ds: &Dataset,
    col: usize,
    rows: &[u32],
    labels: &Labels,
    cfg: &SplitterConfig,
    cache: &mut TrainingCache,
) -> Option<SplitCandidate> {
    // Duplicated rows (bootstrap) need multiplicity, which membership
    // stamps cannot express; fall back to in-sorting in that case. The RF
    // learner does not use presorting for exactly this reason.
    let (epoch, distinct) = cache.mark_members(rows);
    if distinct != rows.len() {
        return split_insort(ds, col, rows, labels, cfg);
    }
    cache.ensure_sorted(ds, col);
    let values = ds.columns[col].as_numerical().expect("numerical column");
    let mut pairs = Vec::with_capacity(rows.len());
    for &r in cache.sorted_order(col) {
        if cache.is_member(r, epoch) {
            pairs.push((values[r as usize], r));
        }
    }
    let missing: Vec<u32> =
        rows.iter().copied().filter(|&r| values[r as usize].is_nan()).collect();
    scan_sorted_pairs(&pairs, &missing, labels, cfg.min_examples).map(|r| SplitCandidate {
        condition: Condition::Higher { attr: col, threshold: r.threshold },
        gain: r.gain,
        missing_to_positive: r.missing_to_positive,
    })
}

/// Approximate histogram splitter (LightGBM-style): bucket values into
/// quantile bins once, then scan per-bin statistics per node.
pub fn split_histogram(
    ds: &Dataset,
    col: usize,
    rows: &[u32],
    labels: &Labels,
    cfg: &SplitterConfig,
    cache: &mut TrainingCache,
    bins: usize,
) -> Option<SplitCandidate> {
    cache.ensure_binned(ds, col, bins);
    let (edges, assignment) = cache.binned_column(col);
    if edges.is_empty() {
        return None;
    }
    let num_bins = edges.len() + 1;
    let mut accs: Vec<ScoreAcc> = (0..num_bins).map(|_| labels.new_acc()).collect();
    let mut bin_counts = vec![0usize; num_bins];
    let mut miss = labels.new_acc();
    let values = ds.columns[col].as_numerical().expect("numerical column");
    let mut sum = 0.0f64;
    let mut n_nonmissing = 0usize;
    for &r in rows {
        let b = assignment[r as usize];
        if b == u16::MAX {
            miss.add(labels, r as usize);
        } else {
            accs[b as usize].add(labels, r as usize);
            bin_counts[b as usize] += 1;
            sum += values[r as usize] as f64;
            n_nonmissing += 1;
        }
    }
    if n_nonmissing < 2 * cfg.min_examples.max(1) {
        return None;
    }
    let mean = (sum / n_nonmissing as f64) as f32;
    let has_missing = miss.count() > 0.0;

    let mut parent = labels.new_acc();
    for a in &accs {
        parent.merge(a);
    }
    parent.merge(&miss);

    // Suffix accumulators: suffix[b] = union of bins b..num_bins, computed
    // once so the scan is O(bins), not O(bins^2).
    let mut suffix: Vec<ScoreAcc> = Vec::with_capacity(num_bins + 1);
    suffix.push(labels.new_acc());
    for a in accs.iter().rev() {
        let mut next = suffix.last().unwrap().clone();
        next.merge(a);
        suffix.push(next);
    }
    suffix.reverse(); // suffix[b] now covers bins b..

    // Scan: left = bins 0..=b (values <= edges[b]), threshold just above
    // edge b. Condition is x >= t, so left is the negative branch.
    let mut left = labels.new_acc();
    let mut n_left = 0usize;
    let mut best: Option<SplitCandidate> = None;
    for b in 0..num_bins - 1 {
        left.merge(&accs[b]);
        n_left += bin_counts[b];
        let n_right = n_nonmissing - n_left;
        if n_left < cfg.min_examples || n_right < cfg.min_examples {
            continue;
        }
        let threshold = next_up(edges[b]);
        let missing_to_positive = mean >= threshold;
        let gain = if has_missing {
            if missing_to_positive {
                let mut r2 = suffix[b + 1].clone();
                r2.merge(&miss);
                ScoreAcc::gain(&parent, &left, &r2, labels)
            } else {
                let mut l2 = left.clone();
                l2.merge(&miss);
                ScoreAcc::gain(&parent, &l2, &suffix[b + 1], labels)
            }
        } else {
            ScoreAcc::gain(&parent, &left, &suffix[b + 1], labels)
        };
        if gain > best.as_ref().map(|b| b.gain).unwrap_or(0.0) {
            best = Some(SplitCandidate {
                condition: Condition::Higher { attr: col, threshold },
                gain,
                missing_to_positive,
            });
        }
    }
    best
}

/// Smallest f32 strictly greater than x (threshold "just above the edge").
fn next_up(x: f32) -> f32 {
    if x.is_nan() || x == f32::INFINITY {
        return x;
    }
    let bits = x.to_bits();
    let next = if x >= 0.0 { bits + 1 } else { bits - 1 };
    f32::from_bits(next)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::dataspec::{ColumnSpec, DataSpec};
    use crate::dataset::ColumnData;
    use crate::utils::rng::Rng;

    fn ds_with(values: Vec<f32>) -> Dataset {
        let spec = DataSpec { columns: vec![ColumnSpec::numerical("x")] };
        Dataset::new(spec, vec![ColumnData::Numerical(values)]).unwrap()
    }

    fn cfg() -> SplitterConfig {
        SplitterConfig { min_examples: 1, ..Default::default() }
    }

    #[test]
    fn insort_finds_obvious_boundary() {
        let ds = ds_with(vec![1.0, 2.0, 3.0, 10.0, 11.0, 12.0]);
        let labels_data = vec![0u32, 0, 0, 1, 1, 1];
        let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
        let rows: Vec<u32> = (0..6).collect();
        let c = split_insort(&ds, 0, &rows, &labels, &cfg()).unwrap();
        match c.condition {
            Condition::Higher { attr, threshold } => {
                assert_eq!(attr, 0);
                assert!((threshold - 6.5).abs() < 1e-6, "threshold {threshold}");
            }
            _ => panic!("wrong condition"),
        }
        assert!(c.gain > 0.0);
    }

    #[test]
    fn presorted_matches_insort() {
        let mut rng = Rng::seed_from_u64(5);
        for _ in 0..20 {
            let n = 30 + rng.uniform_usize(50);
            let values: Vec<f32> =
                (0..n).map(|_| rng.uniform_range(-5.0, 5.0) as f32).collect();
            let labels_data: Vec<u32> =
                values.iter().map(|&v| (v > 0.0) as u32 ^ (rng.bernoulli(0.1) as u32)).collect();
            let ds = ds_with(values);
            let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
            let rows: Vec<u32> = (0..n as u32).filter(|r| r % 3 != 0).collect();
            let mut cache = TrainingCache::new(&ds);
            let a = split_insort(&ds, 0, &rows, &labels, &cfg());
            let b = split_presorted(&ds, 0, &rows, &labels, &cfg(), &mut cache);
            match (a, b) {
                (Some(a), Some(b)) => {
                    assert!((a.gain - b.gain).abs() < 1e-9, "{} vs {}", a.gain, b.gain);
                    match (&a.condition, &b.condition) {
                        (
                            Condition::Higher { threshold: ta, .. },
                            Condition::Higher { threshold: tb, .. },
                        ) => assert_eq!(ta, tb),
                        _ => panic!(),
                    }
                }
                (None, None) => {}
                (a, b) => panic!("mismatch: {a:?} vs {b:?}"),
            }
        }
    }

    #[test]
    fn histogram_close_to_exact_on_separable() {
        let n = 200;
        let mut rng = Rng::seed_from_u64(9);
        let values: Vec<f32> = (0..n).map(|_| rng.uniform_range(0.0, 1.0) as f32).collect();
        let labels_data: Vec<u32> = values.iter().map(|&v| (v > 0.6) as u32).collect();
        let ds = ds_with(values);
        let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
        let rows: Vec<u32> = (0..n as u32).collect();
        let mut cache = TrainingCache::new(&ds);
        let c = split_histogram(&ds, 0, &rows, &labels, &cfg(), &mut cache, 64).unwrap();
        match c.condition {
            Condition::Higher { threshold, .. } => {
                assert!((threshold - 0.6).abs() < 0.05, "threshold {threshold}");
            }
            _ => panic!(),
        }
    }

    #[test]
    fn missing_values_follow_mean() {
        // Mean is in the high block, so missing should go positive.
        let ds = ds_with(vec![1.0, 1.5, 100.0, 101.0, 102.0, f32::NAN]);
        let labels_data = vec![0u32, 0, 1, 1, 1, 1];
        let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
        let rows: Vec<u32> = (0..6).collect();
        let c = split_insort(&ds, 0, &rows, &labels, &cfg()).unwrap();
        assert!(c.missing_to_positive);
    }

    #[test]
    fn constant_feature_yields_none() {
        let ds = ds_with(vec![3.0; 10]);
        let labels_data = vec![0u32, 1, 0, 1, 0, 1, 0, 1, 0, 1];
        let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
        let rows: Vec<u32> = (0..10).collect();
        assert!(split_insort(&ds, 0, &rows, &labels, &cfg()).is_none());
    }

    #[test]
    fn min_examples_respected() {
        let ds = ds_with(vec![1.0, 2.0, 3.0, 4.0]);
        let labels_data = vec![0u32, 1, 1, 1];
        let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
        let rows: Vec<u32> = (0..4).collect();
        let mut c = cfg();
        c.min_examples = 2;
        let best = split_insort(&ds, 0, &rows, &labels, &c).unwrap();
        // The only legal boundary is 2|2.
        match best.condition {
            Condition::Higher { threshold, .. } => {
                assert!((threshold - 2.5).abs() < 1e-6)
            }
            _ => panic!(),
        }
    }

    #[test]
    fn next_up_is_strictly_greater() {
        for x in [0.0f32, 1.0, -1.0, 12345.678, -0.0001] {
            assert!(next_up(x) > x);
        }
    }

    #[test]
    fn regression_split() {
        let ds = ds_with(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let targets = vec![1.0f32, 1.1, 0.9, 5.0, 5.1, 4.9];
        let labels = Labels::Regression { targets: &targets };
        let rows: Vec<u32> = (0..6).collect();
        let c = split_insort(&ds, 0, &rows, &labels, &cfg()).unwrap();
        match c.condition {
            Condition::Higher { threshold, .. } => {
                assert!((threshold - 3.5).abs() < 1e-6)
            }
            _ => panic!(),
        }
    }
}
