//! Text histograms in the YDF report style (Appendix B.1/B.2):
//!
//! ```text
//! [ 23, 25)  1   0.54%   0.54%
//! [ 25, 27)  0   0.00%   0.54% #
//! ```

use crate::utils::stats::Moments;

/// Computes and renders a fixed-bin-count histogram with count, percent and
/// cumulative-percent columns plus a proportional bar, as in the paper's
//  `show_model` output.
pub struct TextHistogram {
    pub moments: Moments,
    values: Vec<f64>,
}

impl Default for TextHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl TextHistogram {
    pub fn new() -> Self {
        TextHistogram { moments: Moments::new(), values: Vec::new() }
    }

    pub fn add(&mut self, x: f64) {
        self.moments.add(x);
        self.values.push(x);
    }

    pub fn extend<I: IntoIterator<Item = f64>>(&mut self, xs: I) {
        for x in xs {
            self.add(x);
        }
    }

    /// Renders with `bins` buckets and a `bar_width`-char max bar.
    pub fn render(&self, bins: usize, bar_width: usize) -> String {
        let n = self.values.len();
        if n == 0 {
            return "  (empty)\n".to_string();
        }
        let mut out = format!(
            "Count: {} Average: {:.5} StdDev: {:.5}\nMin: {} Max: {} Ignored: 0\n----------------------------------------------\n",
            self.moments.count(),
            self.moments.mean(),
            self.moments.std(),
            fmt_num(self.moments.min()),
            fmt_num(self.moments.max()),
        );
        let lo = self.moments.min();
        let hi = self.moments.max();
        let bins = bins.max(1);
        let width = ((hi - lo) / bins as f64).max(f64::MIN_POSITIVE);
        let mut counts = vec![0usize; bins];
        for &v in &self.values {
            let mut b = ((v - lo) / width) as usize;
            if b >= bins {
                b = bins - 1;
            }
            counts[b] += 1;
        }
        let max_count = counts.iter().copied().max().unwrap_or(1).max(1);
        let mut cumulative = 0usize;
        for (i, &c) in counts.iter().enumerate() {
            cumulative += c;
            let b_lo = lo + i as f64 * width;
            let b_hi = if i + 1 == bins { hi } else { lo + (i + 1) as f64 * width };
            let bracket = if i + 1 == bins { "]" } else { ")" };
            let bar = "#".repeat((c * bar_width).div_ceil(max_count).min(bar_width));
            out.push_str(&format!(
                "[ {}, {}{} {} {:.2}% {:.2}% {}\n",
                fmt_num(b_lo),
                fmt_num(b_hi),
                bracket,
                c,
                100.0 * c as f64 / n as f64,
                100.0 * cumulative as f64 / n as f64,
                bar
            ));
        }
        out
    }
}

fn fmt_num(x: f64) -> String {
    if x == x.trunc() && x.abs() < 1e12 {
        format!("{}", x as i64)
    } else {
        format!("{x:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_bins_and_percentages() {
        let mut h = TextHistogram::new();
        h.extend((0..100).map(|i| i as f64));
        let s = h.render(10, 10);
        assert!(s.contains("Count: 100"));
        // 10 equal bins of 10 items each -> every line has 10.00%.
        let bin_lines: Vec<&str> = s.lines().filter(|l| l.starts_with('[')).collect();
        assert_eq!(bin_lines.len(), 10);
        assert!(bin_lines.iter().all(|l| l.contains("10.00%")));
        assert!(bin_lines.last().unwrap().contains("100.00%"));
    }

    #[test]
    fn empty_histogram() {
        let h = TextHistogram::new();
        assert!(h.render(5, 5).contains("empty"));
    }

    #[test]
    fn single_value() {
        let mut h = TextHistogram::new();
        h.add(5.0);
        let s = h.render(4, 4);
        assert!(s.contains("Count: 1"));
    }
}
