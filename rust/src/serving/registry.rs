//! Multi-model serving registry: several named models behind one server.
//!
//! The paper's serving story (§3.7, §5) is one library hosting many
//! models, each pinned to the fastest engine its structure compiles to.
//! A [`Registry`] owns N named [`Session`]s; each entry gets its **own**
//! [`Batcher`] (coalescing only same-model rows — batches must stay
//! single-dataspec so one flush is one `predict_batch`) and its own
//! [`ServingStats`]. Requests route by the top-level `"model"` field of
//! the wire protocol; requests without one go to the **default model**
//! (the first registered), which preserves the PR-3 single-model wire
//! protocol bit for bit.
//!
//! All batchers share one scoring [`WorkerPool`] (resolved from
//! [`BatcherConfig::score_threads`]): flushes larger than one kernel
//! block fan their block spans out across it, so a 512-row coalesced
//! flush no longer scores on one thread — and N models do not multiply
//! the scoring-thread count.

use super::batcher::Batcher;
use super::session::Session;
use super::stats::{aggregate_json, ServingStats};
use super::BatcherConfig;
use crate::utils::json::Json;
use crate::utils::pool::WorkerPool;
use std::collections::HashMap;
use std::sync::Arc;

/// One served model: a session pinned to its engine, the batcher that
/// coalesces its requests, and its telemetry.
pub struct ModelEntry {
    name: String,
    session: Arc<Session>,
    batcher: Arc<Batcher>,
    stats: Arc<ServingStats>,
}

impl ModelEntry {
    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn session(&self) -> &Arc<Session> {
        &self.session
    }

    pub fn batcher(&self) -> &Arc<Batcher> {
        &self.batcher
    }

    pub fn stats(&self) -> &Arc<ServingStats> {
        &self.stats
    }
}

/// Named collection of serving sessions sharing one batching policy and
/// one scoring pool. The first registered model is the default route.
pub struct Registry {
    entries: Vec<ModelEntry>,
    by_name: HashMap<String, usize>,
    batcher_config: BatcherConfig,
    /// Shared across every entry's batcher; `None` when flushes score
    /// single-threaded (`score_threads` resolves to 1).
    score_pool: Option<Arc<WorkerPool>>,
}

impl Registry {
    /// An empty registry; `config` is applied to every model's batcher.
    /// The shared scoring pool is sized from `config.score_threads`
    /// (`0` = the `batch_threads()` default, `1` = no pool).
    pub fn new(config: BatcherConfig) -> Registry {
        let score_pool = config.resolve_score_pool();
        Registry {
            entries: Vec::new(),
            by_name: HashMap::new(),
            batcher_config: config,
            score_pool,
        }
    }

    /// Registers `session` under `name`, spinning up its batcher (and
    /// scorer thread) immediately. Errors on an empty or duplicate name —
    /// misconfiguration reports what is wrong instead of silently
    /// shadowing an already-served model (§2.1).
    pub fn register(&mut self, name: &str, session: Session) -> Result<(), String> {
        if name.is_empty() {
            return Err("model name must not be empty".to_string());
        }
        if self.by_name.contains_key(name) {
            return Err(format!(
                "model '{name}' is already registered; model names must be unique"
            ));
        }
        let session = Arc::new(session);
        let stats = Arc::new(ServingStats::new());
        let batcher = Arc::new(Batcher::with_scoring_pool(
            Arc::clone(&session),
            self.batcher_config.clone(),
            Arc::clone(&stats),
            self.score_pool.clone(),
        ));
        self.by_name.insert(name.to_string(), self.entries.len());
        self.entries.push(ModelEntry { name: name.to_string(), session, batcher, stats });
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Registered model names, in registration order (the first is the
    /// default route).
    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name.as_str()).collect()
    }

    /// The default model: the first registered. Panics on an empty
    /// registry (the server refuses to start on one).
    pub fn default_entry(&self) -> &ModelEntry {
        &self.entries[0]
    }

    pub fn get(&self, name: &str) -> Option<&ModelEntry> {
        self.by_name.get(name).map(|&i| &self.entries[i])
    }

    /// Entries in registration order (index-stable: the position matches
    /// what [`Registry::resolve`] returns, so per-connection scratch can
    /// be indexed by it).
    pub fn entries(&self) -> &[ModelEntry] {
        &self.entries
    }

    /// Routes an optional request `"model"` field to an entry: `None`
    /// means the default model. Unknown names are a clean error listing
    /// what *is* registered — the server turns it into an in-band
    /// `{"error": …}` reply, never a dropped connection.
    pub fn resolve(&self, name: Option<&str>) -> Result<(usize, &ModelEntry), String> {
        match name {
            None => Ok((0, self.default_entry())),
            Some(n) => match self.by_name.get(n) {
                Some(&i) => Ok((i, &self.entries[i])),
                None => Err(format!(
                    "unknown model '{n}'. Registered models: {}.",
                    self.names().join(", ")
                )),
            },
        }
    }

    /// The `{"cmd": "stats"}` payload: aggregate counters at the top
    /// level (single-model shape preserved) plus a per-model breakdown
    /// under `"models"`.
    pub fn stats_json(&self) -> Json {
        let named: Vec<(&str, &ServingStats)> =
            self.entries.iter().map(|e| (e.name.as_str(), e.stats.as_ref())).collect();
        aggregate_json(&named)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::learner::gbt::GbtConfig;
    use crate::learner::{GradientBoostedTreesLearner, Learner};

    fn session(seed: u64, trees: usize) -> Session {
        let ds = synthetic::adult_like(200, seed);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = trees;
        cfg.max_depth = 3;
        Session::new(GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap())
    }

    #[test]
    fn register_resolve_and_default() {
        let mut r = Registry::new(BatcherConfig {
            max_delay: std::time::Duration::ZERO,
            ..Default::default()
        });
        assert!(r.is_empty());
        r.register("a", session(1, 3)).unwrap();
        r.register("b", session(2, 4)).unwrap();
        assert_eq!(r.len(), 2);
        assert_eq!(r.names(), vec!["a", "b"]);
        assert_eq!(r.resolve(None).unwrap().1.name(), "a"); // first = default
        let (idx, b) = r.resolve(Some("b")).unwrap();
        assert_eq!((idx, b.name()), (1, "b"));
        let err = r.resolve(Some("zzz")).unwrap_err();
        assert!(err.contains("zzz") && err.contains("a, b"), "{err}");
    }

    #[test]
    fn duplicate_and_empty_names_rejected() {
        let mut r = Registry::new(BatcherConfig::default());
        r.register("m", session(3, 3)).unwrap();
        assert!(r.register("m", session(4, 3)).unwrap_err().contains("already registered"));
        assert!(r.register("", session(5, 3)).unwrap_err().contains("empty"));
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn per_model_requests_route_to_their_own_batcher_and_stats() {
        let mut r = Registry::new(BatcherConfig {
            max_delay: std::time::Duration::ZERO,
            ..Default::default()
        });
        r.register("a", session(6, 3)).unwrap();
        r.register("b", session(7, 5)).unwrap();
        for (name, n) in [("a", 2usize), ("b", 3usize)] {
            let (_, e) = r.resolve(Some(name)).unwrap();
            for _ in 0..n {
                let mut block = e.session().new_block();
                let row = crate::utils::json::Json::parse(r#"{"age": 33}"#).unwrap();
                e.session().decode_row(&mut block, &row).unwrap();
                let out = e.batcher().submit(&block).unwrap().wait().unwrap();
                assert_eq!(out.len(), e.session().output_dim());
                e.stats().note_request(1, 50.0);
            }
        }
        let j = r.stats_json();
        assert_eq!(j.req_f64("requests").unwrap(), 5.0);
        let models = j.req("models").unwrap();
        assert_eq!(models.req("a").unwrap().req_f64("requests").unwrap(), 2.0);
        assert_eq!(models.req("b").unwrap().req_f64("requests").unwrap(), 3.0);
        // Batches ran on each model's own batcher.
        assert!(models.req("a").unwrap().req_f64("batches").unwrap() >= 1.0);
        assert!(models.req("b").unwrap().req_f64("batches").unwrap() >= 1.0);
    }
}
