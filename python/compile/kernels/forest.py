"""L1 Pallas kernel: blocked, vectorized decision-forest traversal.

The paper's QuickScorer engine (§3.7) exploits CPU bitvector tricks; on
TPU-class hardware the same insight — replace pointer chasing with dense,
branch-free arithmetic — maps to *tensorized traversal*: node attributes
are packed into `[trees, nodes]` tables, and traversal becomes `depth`
rounds of gather + select over an example block resident in VMEM
(DESIGN.md §Hardware-Adaptation).

Grid: one step per tree. Each step keeps one tree's node tables and the
whole example block in VMEM and emits that tree's leaf values for the
block. `interpret=True` everywhere: the CPU PJRT runtime cannot execute
Mosaic custom-calls, and interpret-mode lowering inlines the kernel into
portable HLO (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Padded artifact shapes; must match rust/src/inference/pjrt.rs.
BATCH = 64
MAX_TREES = 64
MAX_NODES = 256
MAX_FEATURES = 16
MAX_DEPTH = 12


def _traverse_kernel(nf_ref, nt_ref, npos_ref, nneg_ref, lv_ref, x_ref, o_ref, *, depth):
    """One grid step: evaluate one tree on the whole example block."""
    nf = nf_ref[...][0]      # [N] node feature, -1 for leaves
    nt = nt_ref[...][0]      # [N] thresholds
    npos = npos_ref[...][0]  # [N] positive child
    nneg = nneg_ref[...][0]  # [N] negative child
    lv = lv_ref[...][0]      # [N] leaf values
    x = x_ref[...]           # [B, F] examples

    b = x.shape[0]
    idx = jnp.zeros((b,), jnp.int32)

    def body(_, idx):
        f = nf[idx]                              # [B]
        is_leaf = f < 0
        fx = jnp.take_along_axis(
            x, jnp.clip(f, 0, x.shape[1] - 1)[:, None], axis=1
        )[:, 0]
        go_pos = fx >= nt[idx]
        nxt = jnp.where(go_pos, npos[idx], nneg[idx])
        return jnp.where(is_leaf, idx, nxt)

    idx = jax.lax.fori_loop(0, depth, body, idx)
    o_ref[...] = lv[idx][None, :]


def forest_traverse(features, node_feature, node_threshold, node_pos, node_neg,
                    leaf_value, *, depth=MAX_DEPTH):
    """Evaluates every tree on every example.

    Args:
      features:       f32[B, F]  (no NaNs; impute before calling)
      node_feature:   i32[T, N]  (-1 marks leaves)
      node_threshold: f32[T, N]
      node_pos:       i32[T, N]
      node_neg:       i32[T, N]
      leaf_value:     f32[T, N]
      depth:          static traversal bound (max tree depth)

    Returns:
      f32[T, B]: the leaf value reached in tree t by example b.
    """
    num_trees, num_nodes = node_feature.shape
    batch, _num_features = features.shape
    kernel = functools.partial(_traverse_kernel, depth=depth)
    return pl.pallas_call(
        kernel,
        grid=(num_trees,),
        in_specs=[
            pl.BlockSpec((1, num_nodes), lambda t: (t, 0)),
            pl.BlockSpec((1, num_nodes), lambda t: (t, 0)),
            pl.BlockSpec((1, num_nodes), lambda t: (t, 0)),
            pl.BlockSpec((1, num_nodes), lambda t: (t, 0)),
            pl.BlockSpec((1, num_nodes), lambda t: (t, 0)),
            pl.BlockSpec(features.shape, lambda t: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, batch), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((num_trees, batch), jnp.float32),
        interpret=True,
    )(node_feature, node_threshold, node_pos, node_neg, leaf_value, features)
