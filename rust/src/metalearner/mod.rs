//! META-LEARNERS (§3.2): learners that wrap other learners. Because a
//! meta-learner *is* a learner, they compose arbitrarily — Figure 3's
//! calibrator(ensembler(tuner(RF), GBT)) is expressible directly.

pub mod calibrator;
pub mod ensembler;
pub mod feature_selector;
pub mod tuner;

pub use calibrator::CalibratorLearner;
pub use ensembler::EnsemblerLearner;
pub use feature_selector::FeatureSelectorLearner;
pub use tuner::{TunerLearner, TunerScoring};
