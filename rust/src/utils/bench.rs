//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides wall-clock timing with warmup, repetition and simple stats, plus
//! table rendering used by the `rust/benches/*` binaries that regenerate the
//! paper's tables and figures.

use std::time::{Duration, Instant};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }
}

/// Times `f` for `iters` iterations after `warmup` iterations.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed());
    }
    summarize(name, &times)
}

/// Times `f` once (for long-running cases such as whole training runs).
pub fn bench_once<F: FnOnce()>(name: &str, f: F) -> BenchResult {
    let t0 = Instant::now();
    f();
    let d = t0.elapsed();
    summarize(name, &[d])
}

fn summarize(name: &str, times: &[Duration]) -> BenchResult {
    let total: Duration = times.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters: times.len(),
        mean: total / times.len() as u32,
        min: *times.iter().min().unwrap(),
        max: *times.iter().max().unwrap(),
    }
}

/// Prevents the optimizer from discarding a value (std::hint::black_box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Fixed-width text table builder for bench/report output, mirroring the
/// paper's table layouts.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.chars().count();
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(c);
                for _ in c.chars().count()..width[i] {
                    line.push(' ');
                }
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &width));
        out.push('\n');
        let total: usize = width.iter().sum::<usize>() + 2 * (ncol - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &width));
            out.push('\n');
        }
        out
    }
}

/// Renders a horizontal ASCII bar chart — used for the Figure 6 mean-rank
/// plot and variable-importance displays (Appendix B.2 style).
pub fn bar_chart(items: &[(String, f64)], max_width: usize) -> String {
    let max_v = items.iter().map(|(_, v)| *v).fold(0.0f64, f64::max).max(1e-12);
    let name_w = items.iter().map(|(n, _)| n.chars().count()).max().unwrap_or(0);
    let mut out = String::new();
    for (name, v) in items {
        let bars = ((v / max_v) * max_width as f64).round() as usize;
        out.push_str(&format!(
            "{name:<name_w$} {v:>8.3} {}\n",
            "#".repeat(bars.max(if *v > 0.0 { 1 } else { 0 }))
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 1, 3, || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
        });
        assert_eq!(r.iters, 3);
        assert!(r.mean >= r.min && r.mean <= r.max);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["Learner", "training (s)", "inference (s)"]);
        t.row(vec!["YDF GBT".into(), "39.99".into(), "0.108".into()]);
        t.row(vec!["LGBM GBT (default)".into(), "4.91".into(), "0.061".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("Learner"));
        assert!(lines[2].contains("39.99"));
    }

    #[test]
    fn bar_chart_scales() {
        let s = bar_chart(
            &[("a".into(), 1.0), ("b".into(), 2.0)],
            10,
        );
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines[1].matches('#').count() == 10);
        assert!(lines[0].matches('#').count() == 5);
    }
}
