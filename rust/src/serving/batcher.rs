//! Micro-batching request coalescer: a bounded submission queue feeding
//! one scorer thread.
//!
//! Concurrent single/multi-row requests are appended, in arrival order,
//! to a shared columnar accumulation block. The scorer flushes — one
//! engine `predict_batch` call over everything pending — when
//!
//! * the pending rows reach [`BatcherConfig::flush_rows`] (a
//!   [`BLOCK_SIZE`] multiple by default, so the engine kernels run full
//!   blocks), or
//! * the *oldest* pending request has waited [`BatcherConfig::max_delay`]
//!   (the latency deadline; `0` means "flush whenever the scorer is
//!   free" — adaptive batching that coalesces only the backlog that
//!   accumulates while the previous batch scores).
//!
//! Results are scattered back to per-request waiters over one-shot
//! channels. Coalescing is pure concatenation and engines are
//! row-independent, so outputs are **bit-identical** to a single
//! `predict_batch` over the same rows (pinned by
//! `rust/tests/serving.rs`).
//!
//! The queue is bounded by [`BatcherConfig::max_queue_rows`]: a submit
//! that would overflow is rejected immediately with
//! [`SubmitError::QueueFull`] — backpressure surfaces to the client as a
//! retryable error instead of unbounded memory growth or an indefinite
//! block.

use super::session::{RowBlock, Session};
use super::stats::ServingStats;
use crate::inference::BLOCK_SIZE;
use crate::utils::pool::WorkerPool;
use std::fmt;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Batching policy knobs. The defaults suit a low-latency online service;
/// the b5 bench and the CLI expose them.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Flush as soon as this many rows are pending. Kept a multiple of
    /// [`BLOCK_SIZE`] by [`Batcher::new`] (rounded up) so coalesced
    /// batches fill whole kernel blocks.
    pub flush_rows: usize,
    /// Latency deadline: flush when the oldest pending request has waited
    /// this long, even if `flush_rows` was not reached. `Duration::ZERO`
    /// disables the wait — the scorer drains whatever is pending the
    /// moment it is free.
    pub max_delay: Duration,
    /// Queue capacity in rows; submissions beyond it are rejected
    /// ([`SubmitError::QueueFull`]). Also the per-request row cap.
    pub max_queue_rows: usize,
    /// Worker threads a flush may fan block spans out over when the
    /// coalesced batch exceeds one [`BLOCK_SIZE`] block (the
    /// `predict_into` contract over persistent `utils/pool.rs` workers).
    /// `0` resolves to [`crate::inference::batch_threads`] (the
    /// `YDF_INFER_THREADS` knob / available parallelism); `1` keeps
    /// flushes single-threaded. Ignored when the batcher is handed a
    /// shared scoring pool ([`Batcher::with_scoring_pool`]).
    pub score_threads: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            flush_rows: BLOCK_SIZE,
            max_delay: Duration::from_millis(2),
            max_queue_rows: 64 * BLOCK_SIZE,
            score_threads: 0,
        }
    }
}

impl BatcherConfig {
    /// Resolves [`BatcherConfig::score_threads`] into a scoring pool:
    /// `None` when flushes should score single-threaded. The single
    /// source of truth for the resolution rule — used by standalone
    /// batchers ([`Batcher::with_stats`]) and shared across a registry's
    /// batchers (`Registry::new`).
    pub fn resolve_score_pool(&self) -> Option<Arc<WorkerPool>> {
        let threads = if self.score_threads == 0 {
            crate::inference::batch_threads()
        } else {
            self.score_threads
        };
        if threads > 1 {
            Some(Arc::new(WorkerPool::new(threads)))
        } else {
            None
        }
    }
}

/// Why a submission was rejected. All variants are immediate — the
/// batcher never blocks a submitter.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// Queue at capacity; retry after in-flight requests drain.
    QueueFull { pending_rows: usize, capacity: usize },
    /// The request alone exceeds the queue capacity and can never be
    /// accepted; split it into smaller requests.
    RequestTooLarge { rows: usize, capacity: usize },
    /// Zero-row requests have no result to wait for.
    EmptyRequest,
    /// The batcher is shutting down.
    Shutdown,
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::QueueFull { pending_rows, capacity } => write!(
                f,
                "serving queue full ({pending_rows}/{capacity} rows pending); retry shortly"
            ),
            SubmitError::RequestTooLarge { rows, capacity } => write!(
                f,
                "request of {rows} rows exceeds the queue capacity of {capacity} rows; \
                 split it into smaller requests"
            ),
            SubmitError::EmptyRequest => write!(f, "request contains no rows"),
            SubmitError::Shutdown => write!(f, "serving batcher is shutting down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// A submitted request's pending result.
pub struct Pending {
    rx: Receiver<Result<Vec<f64>, String>>,
}

impl Pending {
    /// Blocks until the coalesced batch containing this request is scored.
    /// Returns the request's own predictions, row-major
    /// (`rows * output_dim()` values).
    pub fn wait(self) -> Result<Vec<f64>, String> {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => Err("serving batcher shut down before scoring the request".to_string()),
        }
    }
}

struct Waiter {
    /// First row of this request inside the accumulation block.
    start_row: usize,
    rows: usize,
    tx: Sender<Result<Vec<f64>, String>>,
}

struct QueueState {
    /// Arrival-order concatenation of all pending request rows.
    acc: RowBlock,
    waiters: Vec<Waiter>,
    /// Arrival time of the oldest pending request (deadline anchor).
    oldest: Option<Instant>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<QueueState>,
    /// Wakes the scorer on submission and shutdown.
    bell: Condvar,
}

/// The micro-batching coalescer. Clone-free: share it behind an `Arc`.
/// Dropping the batcher flushes and scores everything still pending, then
/// joins the scorer thread — no waiter is left hanging.
pub struct Batcher {
    shared: Arc<Shared>,
    session: Arc<Session>,
    stats: Arc<ServingStats>,
    flush_rows: usize,
    max_queue_rows: usize,
    scorer: Option<std::thread::JoinHandle<()>>,
}

impl Batcher {
    pub fn new(session: Arc<Session>, config: BatcherConfig) -> Batcher {
        Batcher::with_stats(session, config, Arc::new(ServingStats::new()))
    }

    /// As [`Batcher::new`], recording batch/queue counters into `stats`.
    /// The scoring pool is resolved from [`BatcherConfig::score_threads`]
    /// and owned by this batcher alone.
    pub fn with_stats(
        session: Arc<Session>,
        config: BatcherConfig,
        stats: Arc<ServingStats>,
    ) -> Batcher {
        let pool = config.resolve_score_pool();
        Batcher::with_scoring_pool(session, config, stats, pool)
    }

    /// The most general constructor: score large flushes over `score_pool`
    /// when one is given (the registry shares one pool across all of its
    /// models' batchers), single-threaded otherwise. The pool must be
    /// dedicated to scoring — handing over a pool whose workers can block
    /// on serving requests (like the TCP connection pool) would deadlock.
    pub fn with_scoring_pool(
        session: Arc<Session>,
        config: BatcherConfig,
        stats: Arc<ServingStats>,
        score_pool: Option<Arc<WorkerPool>>,
    ) -> Batcher {
        let flush_rows = config.flush_rows.max(1).div_ceil(BLOCK_SIZE) * BLOCK_SIZE;
        let max_queue_rows = config.max_queue_rows.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState {
                acc: session.new_block(),
                waiters: Vec::new(),
                oldest: None,
                shutdown: false,
            }),
            bell: Condvar::new(),
        });
        let scorer = {
            let shared = Arc::clone(&shared);
            let session = Arc::clone(&session);
            let stats = Arc::clone(&stats);
            let max_delay = config.max_delay;
            std::thread::Builder::new()
                .name("ydf-serving-scorer".to_string())
                .spawn(move || {
                    scorer_loop(shared, session, stats, flush_rows, max_delay, score_pool)
                })
                .expect("failed to spawn serving scorer thread")
        };
        Batcher {
            shared,
            session,
            stats,
            flush_rows,
            max_queue_rows,
            scorer: Some(scorer),
        }
    }

    /// The session this batcher scores through.
    pub fn session(&self) -> &Arc<Session> {
        &self.session
    }

    /// Counters shared with the scorer (queue depth, batch sizes).
    pub fn stats(&self) -> &Arc<ServingStats> {
        &self.stats
    }

    /// Rows pending at the threshold that triggers an immediate flush.
    pub fn flush_rows(&self) -> usize {
        self.flush_rows
    }

    /// Queue capacity in rows.
    pub fn capacity_rows(&self) -> usize {
        self.max_queue_rows
    }

    /// Initiates shutdown without waiting: new submissions are rejected
    /// with [`SubmitError::Shutdown`] from this point on, while every
    /// already-accepted request is still scored and answered (the scorer's
    /// drain pass). Idempotent; `Drop` calls it and then joins the scorer.
    pub fn shutdown(&self) {
        // A poisoned lock must not stop the shutdown flag from being set
        // (submitters would keep queueing into a dead batcher): recover
        // the guard — the flag write is valid on any state.
        let mut state = match self.shared.state.lock() {
            Ok(g) => g,
            Err(poisoned) => poisoned.into_inner(),
        };
        state.shutdown = true;
        drop(state);
        self.shared.bell.notify_all();
    }

    /// Enqueues every row of `rows` as one request, copied in arrival
    /// order into the shared accumulation block. Returns immediately —
    /// with a [`Pending`] handle, or with the backpressure error if the
    /// bounded queue cannot take the rows.
    pub fn submit(&self, rows: &RowBlock) -> Result<Pending, SubmitError> {
        let n = rows.rows();
        if n == 0 {
            return Err(SubmitError::EmptyRequest);
        }
        if n > self.max_queue_rows {
            return Err(SubmitError::RequestTooLarge { rows: n, capacity: self.max_queue_rows });
        }
        let (tx, rx) = channel();
        {
            // A poisoned lock means the scorer thread panicked: the
            // batcher can never score again, which to a submitter is
            // indistinguishable from shutdown. Answering with an error —
            // instead of propagating the panic — keeps server workers
            // alive to deliver the error reply (serving/server.rs audit).
            let mut state = match self.shared.state.lock() {
                Ok(s) => s,
                Err(_) => return Err(SubmitError::Shutdown),
            };
            if state.shutdown {
                return Err(SubmitError::Shutdown);
            }
            let pending = state.acc.rows();
            if pending + n > self.max_queue_rows {
                self.stats.note_rejected();
                return Err(SubmitError::QueueFull {
                    pending_rows: pending,
                    capacity: self.max_queue_rows,
                });
            }
            state.acc.append_from(rows);
            state.waiters.push(Waiter { start_row: pending, rows: n, tx });
            if state.oldest.is_none() {
                state.oldest = Some(Instant::now());
            }
            self.stats.set_queue_rows(state.acc.rows());
        }
        self.shared.bell.notify_one();
        Ok(Pending { rx })
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(h) = self.scorer.take() {
            let _ = h.join();
        }
    }
}

fn scorer_loop(
    shared: Arc<Shared>,
    session: Arc<Session>,
    stats: Arc<ServingStats>,
    flush_rows: usize,
    max_delay: Duration,
    score_pool: Option<Arc<WorkerPool>>,
) {
    // If this thread unwinds (an engine panic, a lost scoped job), fail
    // open: mark shutdown so later submissions get an error reply instead
    // of queueing forever, and drop the queued waiters so their
    // `Pending::wait` returns the shutdown error instead of blocking on a
    // channel nobody will ever answer. Without this, a scorer panic that
    // strikes outside the lock (the common case — scoring runs with the
    // lock released) leaves the mutex unpoisoned and the whole server
    // wedges silently. On a clean exit the guard is a no-op: shutdown is
    // already set and the waiter list is empty.
    struct FailOpen(Arc<Shared>);
    impl Drop for FailOpen {
        fn drop(&mut self) {
            // Recover a poisoned lock rather than skip: leaving the
            // waiters in place would hang their Pending::wait forever —
            // the exact wedge this guard exists to prevent. Setting the
            // flag and dropping the senders is valid on any state.
            let mut state = match self.0.state.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            state.shutdown = true;
            state.waiters.clear();
            drop(state);
            self.0.bell.notify_all();
        }
    }
    let _fail_open = FailOpen(Arc::clone(&shared));
    // Double buffer: while one block scores, submissions fill the other.
    // `spare` is moved into the queue at flush and recovered (cleared)
    // after scattering, so steady-state flushing allocates nothing.
    let mut spare = session.new_block();
    let mut state = shared.state.lock().expect("serving queue poisoned");
    loop {
        // Wait for work or a flush condition. Spurious wakeups just
        // re-evaluate the conditions.
        loop {
            let pending = state.acc.rows();
            if state.shutdown {
                break; // flush the remainder, then exit below
            }
            if pending >= flush_rows {
                break;
            }
            if pending > 0 {
                let age = state.oldest.map(|t| t.elapsed()).unwrap_or(Duration::ZERO);
                if age >= max_delay {
                    break;
                }
                let (s, _timeout) = shared
                    .bell
                    .wait_timeout(state, max_delay - age)
                    .expect("serving queue poisoned");
                state = s;
            } else {
                state = shared.bell.wait(state).expect("serving queue poisoned");
            }
        }
        if state.acc.rows() == 0 {
            if state.shutdown {
                return;
            }
            continue;
        }
        // Take the whole pending batch; submissions continue concurrently
        // into the spare block while this one scores.
        let mut batch = std::mem::replace(&mut state.acc, spare);
        let waiters = std::mem::take(&mut state.waiters);
        state.oldest = None;
        let exiting = state.shutdown;
        stats.set_queue_rows(0);
        drop(state);

        let dim = session.output_dim();
        // Large coalesced batches fan block spans out across the scoring
        // pool (bit-identical to the single-call path); small ones score
        // inline on this thread.
        let out = session.predict_block_pooled(&mut batch, score_pool.as_deref());
        stats.note_batch(batch.rows(), waiters.len());
        for w in waiters {
            let chunk = out[w.start_row * dim..(w.start_row + w.rows) * dim].to_vec();
            // A submitter that dropped its Pending just doesn't collect.
            let _ = w.tx.send(Ok(chunk));
        }
        batch.clear();
        spare = batch;
        if exiting {
            // One drain pass under shutdown: anything submitted between
            // the flush and now still gets scored on the next iteration;
            // `submit` rejects new work once `shutdown` is set, so this
            // terminates.
            state = shared.state.lock().expect("serving queue poisoned");
            if state.acc.rows() == 0 {
                return;
            }
            continue;
        }
        state = shared.state.lock().expect("serving queue poisoned");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::synthetic;
    use crate::learner::gbt::GbtConfig;
    use crate::learner::{GradientBoostedTreesLearner, Learner};
    use crate::utils::json::Json;

    fn session() -> Arc<Session> {
        let ds = synthetic::adult_like(300, 99);
        let mut cfg = GbtConfig::new("income");
        cfg.num_trees = 4;
        cfg.max_depth = 4;
        Arc::new(Session::new(GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap()))
    }

    fn one_row(s: &Session, age: f64) -> RowBlock {
        let mut b = s.new_block();
        let row = Json::parse(&format!(r#"{{"age": {age}, "education": "Masters"}}"#)).unwrap();
        s.decode_row(&mut b, &row).unwrap();
        b
    }

    #[test]
    fn single_request_scores_after_deadline() {
        let s = session();
        let b = Batcher::new(
            Arc::clone(&s),
            BatcherConfig { max_delay: Duration::from_millis(1), ..Default::default() },
        );
        let block = one_row(&s, 40.0);
        let out = b.submit(&block).unwrap().wait().unwrap();
        assert_eq!(out.len(), s.output_dim());
        assert!((out.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn zero_delay_drains_immediately() {
        let s = session();
        let b = Batcher::new(
            Arc::clone(&s),
            BatcherConfig { max_delay: Duration::ZERO, ..Default::default() },
        );
        for _ in 0..3 {
            let block = one_row(&s, 33.0);
            let out = b.submit(&block).unwrap().wait().unwrap();
            assert_eq!(out.len(), s.output_dim());
        }
        assert!(b.stats().snapshot().batches >= 1);
    }

    #[test]
    fn empty_and_oversized_requests_rejected() {
        let s = session();
        let b = Batcher::new(
            Arc::clone(&s),
            BatcherConfig { max_queue_rows: 4, ..Default::default() },
        );
        assert_eq!(b.submit(&s.new_block()).unwrap_err(), SubmitError::EmptyRequest);
        let mut big = s.new_block();
        for _ in 0..5 {
            big.append_from(&one_row(&s, 30.0));
        }
        assert!(matches!(
            b.submit(&big).unwrap_err(),
            SubmitError::RequestTooLarge { rows: 5, capacity: 4 }
        ));
    }

    #[test]
    fn flush_rows_rounds_up_to_block_multiple() {
        let s = session();
        let b = Batcher::new(
            Arc::clone(&s),
            BatcherConfig { flush_rows: 65, ..Default::default() },
        );
        assert_eq!(b.flush_rows(), 2 * crate::inference::BLOCK_SIZE);
    }

    #[test]
    fn pooled_flush_bit_identical_to_single_call() {
        let s = session();
        // A multi-block request forced through a 3-worker scoring pool
        // must not change a single bit vs the single-threaded score.
        let b = Batcher::with_scoring_pool(
            Arc::clone(&s),
            BatcherConfig { max_delay: Duration::ZERO, ..Default::default() },
            Arc::new(ServingStats::new()),
            Some(Arc::new(crate::utils::pool::WorkerPool::new(3))),
        );
        let mut big = s.new_block();
        for i in 0..201 {
            // Unaligned tail (201 = 3*64 + 9) and varied feature values.
            big.append_from(&one_row(&s, 20.0 + (i % 45) as f64));
        }
        let mut reference_block = s.new_block();
        reference_block.append_from(&big);
        let reference = s.predict_block(&mut reference_block);
        let out = b.submit(&big).unwrap().wait().unwrap();
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
        assert_eq!(bits(&out), bits(&reference));
    }

    #[test]
    fn explicit_shutdown_rejects_new_and_drains_accepted() {
        let s = session();
        let b = Batcher::new(
            Arc::clone(&s),
            BatcherConfig {
                max_delay: Duration::from_secs(30),
                flush_rows: 1024,
                ..Default::default()
            },
        );
        let pending = b.submit(&one_row(&s, 41.0)).unwrap();
        b.shutdown();
        assert_eq!(b.submit(&one_row(&s, 42.0)).unwrap_err(), SubmitError::Shutdown);
        // The accepted request is still scored by the drain pass.
        assert_eq!(pending.wait().unwrap().len(), s.output_dim());
    }

    #[test]
    fn drop_flushes_pending_requests() {
        let s = session();
        let b = Batcher::new(
            Arc::clone(&s),
            // Deadline far away, flush threshold unreachable: only the
            // shutdown drain can score this request.
            BatcherConfig {
                max_delay: Duration::from_secs(30),
                flush_rows: 1024,
                ..Default::default()
            },
        );
        let block = one_row(&s, 55.0);
        let pending = b.submit(&block).unwrap();
        drop(b);
        let out = pending.wait().unwrap();
        assert_eq!(out.len(), s.output_dim());
    }
}
