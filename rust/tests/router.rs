//! Integration tests for measured engine routing
//! (`rust/src/inference/router.rs`): the calibration table caches next
//! to the model file and round-trips through a real session reopen,
//! hostile tables (every stepped truncation and bit flip, plus a stale
//! fingerprint) degrade to the static engine order without an error,
//! and a calibration-routed session answers the exact same bits as a
//! static one at every batch-size bucket.

mod common;

use common::{adult_gbt, adult_json_rows, decode_all};
use std::path::PathBuf;
use ydf::inference::router::{self, CalibrateMode};
use ydf::model::io::save_model;
use ydf::serving::Session;

/// Bitwise f64 comparison: routing must only ever change *which*
/// bit-identical engine runs, so the contract is exact bits, not
/// approximate equality.
fn assert_bits_eq(got: &[f64], want: &[f64], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            g.to_bits() == w.to_bits(),
            "{what}: value {i} differs: {g} (bits {:#x}) vs {w} (bits {:#x})",
            g.to_bits(),
            w.to_bits()
        );
    }
}

/// Fresh per-test scratch directory under the system temp dir.
fn scratch_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ydf_router_{name}"));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// First calibrated open measures and writes `<model>.router.json`;
/// the second open consumes the cached table byte-for-byte (the file is
/// not rewritten) and routes every bucket the same way. `Off` ignores
/// the cache; `Force` re-measures and rewrites it as a valid table.
#[test]
fn calibration_table_caches_next_to_the_model_and_reloads() {
    let dir = scratch_dir("cache");
    let path = dir.join("model.json");
    save_model(adult_gbt(300, 0xCA11, 5, 4).as_ref(), &path).unwrap();

    let first = Session::open_with(&path, CalibrateMode::Load).unwrap();
    assert!(first.router_calibrated(), "first open measures and calibrates");
    let table = router::table_path(&path);
    assert!(table.is_file(), "calibration is cached next to the model");
    let cached = std::fs::read_to_string(&table).unwrap();

    let second = Session::open_with(&path, CalibrateMode::Load).unwrap();
    assert!(second.router_calibrated(), "second open reuses the cache");
    assert_eq!(
        std::fs::read_to_string(&table).unwrap(),
        cached,
        "a cache hit must not rewrite the table"
    );
    for &rows in &router::BUCKETS {
        assert_eq!(
            first.engine_name_for_rows(rows),
            second.engine_name_for_rows(rows),
            "bucket {rows}: the cached table must reproduce the measured routing"
        );
    }

    let off = Session::open_with(&path, CalibrateMode::Off).unwrap();
    assert!(!off.router_calibrated(), "Off pins the static order despite the cache");

    let forced = Session::open_with(&path, CalibrateMode::Force).unwrap();
    assert!(forced.router_calibrated(), "Force re-measures");
    let rewritten = std::fs::read_to_string(&table).unwrap();
    assert!(
        router::CalibrationTable::from_file_string(&rewritten).is_ok(),
        "Force leaves a valid table behind"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Hostile cached tables: every stepped single-bit corruption and
/// truncation of a valid table — and a structurally valid table whose
/// fingerprint no longer matches the model — must open cleanly with the
/// static engine order, exactly like a session with no table at all.
/// Mirrors `hostile_artifacts_rejected_not_panicked` in `compiled.rs`,
/// except the router's contract is *fallback*, not error.
#[test]
fn hostile_calibration_tables_fall_back_to_static_order() {
    let dir = scratch_dir("hostile");
    let path = dir.join("model.json");
    save_model(adult_gbt(300, 0xBAD5EED, 5, 4).as_ref(), &path).unwrap();

    let baseline = Session::open_with(&path, CalibrateMode::Off).unwrap();
    // Seed a valid cache, then corrupt it in place.
    Session::open_with(&path, CalibrateMode::Load).unwrap();
    let table = router::table_path(&path);
    let bytes = std::fs::read(&table).unwrap();
    let expect_static = |s: &Session, what: &str| {
        assert!(!s.router_calibrated(), "{what}: must fall back to the static order");
        for &rows in &router::BUCKETS {
            assert_eq!(
                s.engine_name_for_rows(rows),
                baseline.engine_name_for_rows(rows),
                "{what}: bucket {rows} must route as the static order does"
            );
        }
    };

    // Single-bit flips stepped across the file — header, checksum field,
    // payload, whitespace. The checksum covers the exact payload bytes
    // and the header fields are each validated, so every flip must be
    // detected and degrade to the static order (never re-measured: a
    // silently rewritten cache would mask the corruption).
    for pos in (0..bytes.len()).step_by(29) {
        let mut corrupt = bytes.clone();
        corrupt[pos] ^= 0x10;
        std::fs::write(&table, &corrupt).unwrap();
        let s = Session::open_with(&path, CalibrateMode::Load).unwrap();
        expect_static(&s, &format!("bit flip at byte {pos}"));
        assert_eq!(
            std::fs::read(&table).unwrap(),
            corrupt,
            "bit flip at byte {pos}: the bad cache must not be rewritten"
        );
    }

    // Truncations stepped across the file, plus the empty file.
    let mut lengths: Vec<usize> = (0..bytes.len()).step_by(37).collect();
    lengths.extend([0, 1, bytes.len() - 1]);
    for len in lengths {
        std::fs::write(&table, &bytes[..len]).unwrap();
        let s = Session::open_with(&path, CalibrateMode::Load).unwrap();
        expect_static(&s, &format!("truncation to {len} bytes"));
    }

    // A well-formed table for a *different* model: the fingerprint check
    // must reject it as stale.
    std::fs::write(&table, &bytes).unwrap();
    save_model(adult_gbt(300, 0xD1FF, 7, 4).as_ref(), &path).unwrap();
    let stale_baseline = Session::open_with(&path, CalibrateMode::Off).unwrap();
    let s = Session::open_with(&path, CalibrateMode::Load).unwrap();
    assert!(!s.router_calibrated(), "stale fingerprint must fall back");
    for &rows in &router::BUCKETS {
        assert_eq!(s.engine_name_for_rows(rows), stale_baseline.engine_name_for_rows(rows));
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The routing bit-identity contract over the full file-backed serving
/// path: a `Force`-calibrated session and an `Off` (static) session
/// opened from the same model file answer identical bits for the same
/// decoded requests at every bucket's row count — whatever engine the
/// measurement happened to pick per bucket.
#[test]
fn routed_session_bit_identical_to_static_at_every_bucket() {
    let dir = scratch_dir("bit_identity");
    let path = dir.join("model.json");
    save_model(adult_gbt(500, 0xB17, 8, 4).as_ref(), &path).unwrap();

    let routed = Session::open_with(&path, CalibrateMode::Force).unwrap();
    let fixed = Session::open_with(&path, CalibrateMode::Off).unwrap();
    assert!(routed.router_calibrated());
    assert!(!fixed.router_calibrated());

    // One past each bucket boundary too, so re-routing by actual row
    // count (not just exact bucket sizes) is covered. Rows include
    // missing numericals and out-of-dictionary categories.
    let requests = adult_json_rows(512);
    for n in [1usize, 2, 3, 8, 23, 64, 181, 182, 512] {
        let mut routed_block = decode_all(&routed, &requests[..n]);
        let mut fixed_block = decode_all(&fixed, &requests[..n]);
        let got = routed.predict_block(&mut routed_block);
        let want = fixed.predict_block(&mut fixed_block);
        assert_bits_eq(&got, &want, &format!("{n} rows"));
    }
    std::fs::remove_dir_all(&dir).ok();
}
