//! Prometheus text exposition (format version 0.0.4) rendering for the
//! [`super::metrics`] registry.
//!
//! [`render_global`] walks the registry snapshot and emits one
//! `# HELP` / `# TYPE` header plus one sample line per labeled series.
//! The serving layer prepends its own per-model families (rendered from
//! `ServingStats` snapshots in `serving::Registry::prometheus`, which
//! keeps `obs` free of serving dependencies) using the same
//! [`family_header`] / [`sample`] helpers, so both halves share escaping
//! and formatting rules.

use super::metrics;

/// Renders every family in the global registry. Deterministic order
/// (families by name, series by sorted label pairs).
pub fn render_global() -> String {
    let mut out = String::new();
    for family in metrics().snapshot() {
        family_header(&mut out, family.name, family.help, family.kind.name());
        for (labels, value) in &family.series {
            let pairs: Vec<(&str, &str)> =
                labels.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
            sample(&mut out, family.name, &pairs, *value as f64);
        }
    }
    out
}

/// Appends the `# HELP` / `# TYPE` header for one metric family.
/// `kind` is the Prometheus type string: `counter`, `gauge`, `summary`.
pub fn family_header(out: &mut String, name: &str, help: &str, kind: &str) {
    out.push_str("# HELP ");
    out.push_str(name);
    out.push(' ');
    // HELP text is a single line; escape backslash and newline per spec.
    for c in help.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out.push('\n');
    out.push_str("# TYPE ");
    out.push_str(name);
    out.push(' ');
    out.push_str(kind);
    out.push('\n');
}

/// Appends one sample line: `name{label="value",...} value`. Label
/// values get the spec's escaping (backslash, double quote, newline);
/// non-finite values render as `0` (the registry only holds integers, but
/// serving-side summaries pass computed f64s through here).
pub fn sample(out: &mut String, name: &str, labels: &[(&str, &str)], value: f64) {
    out.push_str(name);
    if !labels.is_empty() {
        out.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(k);
            out.push_str("=\"");
            for c in v.chars() {
                match c {
                    '\\' => out.push_str("\\\\"),
                    '"' => out.push_str("\\\""),
                    '\n' => out.push_str("\\n"),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        out.push('}');
    }
    out.push(' ');
    let value = if value.is_finite() { value } else { 0.0 };
    if value == value.trunc() && value.abs() < 1e15 {
        out.push_str(&format!("{}", value as i64));
    } else {
        out.push_str(&format!("{value}"));
    }
    out.push('\n');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_lines_match_exposition_syntax() {
        let mut out = String::new();
        family_header(&mut out, "ydf_test_prom_total", "a test\nfamily", "counter");
        sample(&mut out, "ydf_test_prom_total", &[], 3.0);
        sample(
            &mut out,
            "ydf_test_prom_total",
            &[("engine", "a\"b\\c"), ("model", "m")],
            1.5,
        );
        sample(&mut out, "ydf_test_prom_total", &[("engine", "nan")], f64::NAN);
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines[0], "# HELP ydf_test_prom_total a test\\nfamily");
        assert_eq!(lines[1], "# TYPE ydf_test_prom_total counter");
        assert_eq!(lines[2], "ydf_test_prom_total 3");
        assert_eq!(
            lines[3],
            "ydf_test_prom_total{engine=\"a\\\"b\\\\c\",model=\"m\"} 1.5"
        );
        assert_eq!(lines[4], "ydf_test_prom_total{engine=\"nan\"} 0");
    }

    #[test]
    fn global_render_includes_registered_series() {
        let c = metrics().counter_with(
            "ydf_test_prom_global_total",
            "exposition test counter",
            &[("case", "render")],
        );
        c.add(2);
        let text = render_global();
        assert!(text.contains("# TYPE ydf_test_prom_global_total counter"));
        assert!(text.contains("ydf_test_prom_global_total{case=\"render\"}"));
        // Every non-comment line is `name[{labels}] value`.
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let (name_part, value_part) =
                line.rsplit_once(' ').expect("sample has a value");
            assert!(value_part.parse::<f64>().is_ok(), "bad value in: {line}");
            let name = name_part.split('{').next().unwrap();
            assert!(
                name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':'),
                "bad metric name in: {line}"
            );
        }
    }
}
