//! Categorical, boolean and categorical-set splitters (§3.8).
//!
//! Three categorical algorithms, matching the paper's inventory: CART
//! (exact ordering trick, Fisher 1958 — like LightGBM), Random (random
//! set projections, Breiman — benchmark hp), and OneHot (one category vs
//! rest — how XGBoost/scikit-learn behave after one-hot encoding).

use super::score::{Labels, ScoreAcc};
use super::{CategoricalSplit, SplitCandidate, SplitterConfig};
use crate::dataset::{ColumnData, Dataset, MISSING_CAT};
use crate::model::tree::{bitmap_from_items, Condition};
use crate::utils::rng::Rng;

/// Per-category accumulators + missing accumulator for a node.
struct CatStats {
    per_cat: Vec<ScoreAcc>,
    cat_counts: Vec<usize>,
    miss: ScoreAcc,
    parent: ScoreAcc,
    n_nonmissing: usize,
    /// Most frequent category in the node (local imputation target).
    most_frequent: usize,
}

fn collect_cat_stats(
    ds: &Dataset,
    col: usize,
    rows: &[u32],
    labels: &Labels,
    vocab: usize,
) -> CatStats {
    let values = match &ds.columns[col] {
        ColumnData::Categorical(v) => v,
        _ => panic!("categorical splitter on non-categorical column"),
    };
    let mut per_cat: Vec<ScoreAcc> = (0..vocab).map(|_| labels.new_acc()).collect();
    let mut cat_counts = vec![0usize; vocab];
    let mut miss = labels.new_acc();
    let mut parent = labels.new_acc();
    let mut n_nonmissing = 0usize;
    for &r in rows {
        let c = values[r as usize];
        parent.add(labels, r as usize);
        if c == MISSING_CAT || (c as usize) >= vocab {
            miss.add(labels, r as usize);
        } else {
            per_cat[c as usize].add(labels, r as usize);
            cat_counts[c as usize] += 1;
            n_nonmissing += 1;
        }
    }
    let most_frequent = cat_counts
        .iter()
        .enumerate()
        .max_by_key(|(_, &c)| c)
        .map(|(i, _)| i)
        .unwrap_or(0);
    CatStats { per_cat, cat_counts, miss, parent, n_nonmissing, most_frequent }
}

/// Evaluates the split "x ∈ positive_set", with missing imputed to the
/// node's most frequent category.
fn eval_set_split(
    stats: &CatStats,
    positive: &[bool],
    labels: &Labels,
    min_examples: usize,
) -> Option<f64> {
    let mut pos = labels.new_acc();
    let mut neg = labels.new_acc();
    let mut n_pos = 0usize;
    let mut n_neg = 0usize;
    for (c, in_pos) in positive.iter().enumerate() {
        if stats.cat_counts[c] == 0 {
            continue;
        }
        if *in_pos {
            pos.merge(&stats.per_cat[c]);
            n_pos += stats.cat_counts[c];
        } else {
            neg.merge(&stats.per_cat[c]);
            n_neg += stats.cat_counts[c];
        }
    }
    if n_pos < min_examples || n_neg < min_examples {
        return None;
    }
    if stats.miss.count() > 0.0 {
        if positive[stats.most_frequent] {
            pos.merge(&stats.miss);
        } else {
            neg.merge(&stats.miss);
        }
    }
    Some(ScoreAcc::gain(&stats.parent, &pos, &neg, labels))
}

/// Dispatch by configured algorithm.
pub fn split_categorical(
    ds: &Dataset,
    col: usize,
    rows: &[u32],
    labels: &Labels,
    cfg: &SplitterConfig,
    rng: &mut Rng,
) -> Option<SplitCandidate> {
    let vocab = ds.spec.columns[col].vocab_size();
    if vocab < 2 {
        return None;
    }
    let stats = collect_cat_stats(ds, col, rows, labels, vocab);
    if stats.n_nonmissing < 2 * cfg.min_examples.max(1) {
        return None;
    }
    let best_set: Option<(Vec<bool>, f64)> = match cfg.categorical {
        CategoricalSplit::Cart => cart_best_set(&stats, labels, cfg.min_examples),
        CategoricalSplit::Random { trials } => {
            random_best_set(&stats, labels, cfg.min_examples, trials, rng)
        }
        CategoricalSplit::OneHot => onehot_best_set(&stats, labels, cfg.min_examples),
    };
    best_set.map(|(positive, gain)| {
        let items: Vec<u32> = positive
            .iter()
            .enumerate()
            .filter(|(_, &p)| p)
            .map(|(c, _)| c as u32)
            .collect();
        SplitCandidate {
            condition: Condition::ContainsBitmap {
                attr: col,
                bitmap: bitmap_from_items(&items, vocab),
            },
            gain,
            missing_to_positive: positive[stats.most_frequent],
        }
    })
}

/// CART: order categories by their label statistic, scan prefix splits.
/// Exact for binary classification and regression (Fisher 1958).
fn cart_best_set(
    stats: &CatStats,
    labels: &Labels,
    min_examples: usize,
) -> Option<(Vec<bool>, f64)> {
    let vocab = stats.per_cat.len();
    let mut present: Vec<usize> = (0..vocab).filter(|&c| stats.cat_counts[c] > 0).collect();
    if present.len() < 2 {
        return None;
    }
    present.sort_by(|&a, &b| {
        stats.per_cat[a]
            .ordering_statistic(labels)
            .partial_cmp(&stats.per_cat[b].ordering_statistic(labels))
            .unwrap()
    });
    let mut best: Option<(Vec<bool>, f64)> = None;
    let mut positive = vec![false; vocab];
    // Prefix scan over the ordering: positive set = categories seen so far.
    for i in 0..present.len() - 1 {
        positive[present[i]] = true;
        if let Some(gain) = eval_set_split(stats, &positive, labels, min_examples) {
            if gain > best.as_ref().map(|b| b.1).unwrap_or(0.0) {
                best = Some((positive.clone(), gain));
            }
        }
    }
    best
}

/// Random: evaluate `trials` random subsets, keep the best (Breiman's
/// random categorical projection; `categorical_algorithm: RANDOM`).
fn random_best_set(
    stats: &CatStats,
    labels: &Labels,
    min_examples: usize,
    trials: usize,
    rng: &mut Rng,
) -> Option<(Vec<bool>, f64)> {
    let vocab = stats.per_cat.len();
    let present: Vec<usize> = (0..vocab).filter(|&c| stats.cat_counts[c] > 0).collect();
    if present.len() < 2 {
        return None;
    }
    let mut best: Option<(Vec<bool>, f64)> = None;
    for _ in 0..trials {
        let mut positive = vec![false; vocab];
        let mut any = false;
        let mut all = true;
        for &c in &present {
            if rng.bernoulli(0.5) {
                positive[c] = true;
                any = true;
            } else {
                all = false;
            }
        }
        if !any || all {
            continue;
        }
        if let Some(gain) = eval_set_split(stats, &positive, labels, min_examples) {
            if gain > best.as_ref().map(|b| b.1).unwrap_or(0.0) {
                best = Some((positive, gain));
            }
        }
    }
    best
}

/// OneHot: each category alone vs the rest — mirrors what libraries without
/// native categorical support explore after one-hot encoding.
fn onehot_best_set(
    stats: &CatStats,
    labels: &Labels,
    min_examples: usize,
) -> Option<(Vec<bool>, f64)> {
    let vocab = stats.per_cat.len();
    let mut best: Option<(Vec<bool>, f64)> = None;
    for c in 0..vocab {
        if stats.cat_counts[c] == 0 {
            continue;
        }
        let mut positive = vec![false; vocab];
        positive[c] = true;
        if let Some(gain) = eval_set_split(stats, &positive, labels, min_examples) {
            if gain > best.as_ref().map(|b| b.1).unwrap_or(0.0) {
                best = Some((positive, gain));
            }
        }
    }
    best
}

/// Boolean splitter: the single candidate `x == true`.
pub fn split_boolean(
    ds: &Dataset,
    col: usize,
    rows: &[u32],
    labels: &Labels,
    cfg: &SplitterConfig,
) -> Option<SplitCandidate> {
    let values = match &ds.columns[col] {
        ColumnData::Boolean(v) => v,
        _ => return None,
    };
    let mut pos = labels.new_acc();
    let mut neg = labels.new_acc();
    let mut miss = labels.new_acc();
    let mut parent = labels.new_acc();
    let (mut n_pos, mut n_neg, mut n_true_like) = (0usize, 0usize, 0usize);
    for &r in rows {
        parent.add(labels, r as usize);
        match values[r as usize] {
            1 => {
                pos.add(labels, r as usize);
                n_pos += 1;
                n_true_like += 1;
            }
            0 => {
                neg.add(labels, r as usize);
                n_neg += 1;
            }
            _ => miss.add(labels, r as usize),
        }
    }
    if n_pos < cfg.min_examples || n_neg < cfg.min_examples {
        return None;
    }
    // Missing imputes to the majority value in the node.
    let missing_to_positive = n_true_like * 2 > n_pos + n_neg;
    if miss.count() > 0.0 {
        if missing_to_positive {
            pos.merge(&miss);
        } else {
            neg.merge(&miss);
        }
    }
    let gain = ScoreAcc::gain(&parent, &pos, &neg, labels);
    Some(SplitCandidate {
        condition: Condition::IsTrue { attr: col },
        gain,
        missing_to_positive,
    })
}

/// Categorical-set splitter (§3.8, Guillame-Bert et al. 2020): greedily
/// grows the positive token set in decreasing singleton-gain order while
/// the gain improves.
pub fn split_categorical_set(
    ds: &Dataset,
    col: usize,
    rows: &[u32],
    labels: &Labels,
    cfg: &SplitterConfig,
) -> Option<SplitCandidate> {
    let vocab = ds.spec.columns[col].vocab_size();
    if vocab == 0 {
        return None;
    }
    let col_data = &ds.columns[col];
    // Evaluate "example's set intersects `mask`".
    let eval_mask = |mask: &[u64]| -> Option<(f64, bool)> {
        let mut pos = labels.new_acc();
        let mut neg = labels.new_acc();
        let mut miss = labels.new_acc();
        let mut parent = labels.new_acc();
        let (mut n_pos, mut n_neg) = (0usize, 0usize);
        for &r in rows {
            parent.add(labels, r as usize);
            if col_data.is_missing(r as usize) {
                miss.add(labels, r as usize);
                continue;
            }
            let hit = col_data
                .set_values(r as usize)
                .map(|items| {
                    items.iter().any(|&i| crate::model::tree::bitmap_contains(mask, i))
                })
                .unwrap_or(false);
            if hit {
                pos.add(labels, r as usize);
                n_pos += 1;
            } else {
                neg.add(labels, r as usize);
                n_neg += 1;
            }
        }
        if n_pos < cfg.min_examples || n_neg < cfg.min_examples {
            return None;
        }
        // Missing sets impute to the negative (no-intersection) branch.
        neg.merge(&miss);
        Some((ScoreAcc::gain(&parent, &pos, &neg, labels), false))
    };

    // Singleton gains for the most frequent tokens.
    let max_tokens = 32usize.min(vocab);
    let mut singles: Vec<(u32, f64)> = Vec::new();
    for t in 0..max_tokens as u32 {
        let mask = bitmap_from_items(&[t], vocab);
        if let Some((gain, _)) = eval_mask(&mask) {
            singles.push((t, gain));
        }
    }
    singles.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    if singles.is_empty() {
        return None;
    }
    // Greedy growth.
    let mut chosen = vec![singles[0].0];
    let mut best_gain = singles[0].1;
    for &(t, _) in &singles[1..] {
        let mut candidate = chosen.clone();
        candidate.push(t);
        let mask = bitmap_from_items(&candidate, vocab);
        if let Some((gain, _)) = eval_mask(&mask) {
            if gain > best_gain {
                best_gain = gain;
                chosen = candidate;
            }
        }
    }
    Some(SplitCandidate {
        condition: Condition::ContainsSetBitmap {
            attr: col,
            bitmap: bitmap_from_items(&chosen, vocab),
        },
        gain: best_gain,
        missing_to_positive: false,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::dataspec::{ColumnSpec, DataSpec};
    use crate::model::tree::bitmap_contains;

    fn cat_ds(values: Vec<u32>, vocab: usize) -> Dataset {
        let dict: Vec<String> = (0..vocab).map(|i| format!("v{i}")).collect();
        let spec = DataSpec { columns: vec![ColumnSpec::categorical("c", dict)] };
        Dataset::new(spec, vec![ColumnData::Categorical(values)]).unwrap()
    }

    fn cfg() -> SplitterConfig {
        SplitterConfig { min_examples: 1, ..Default::default() }
    }

    #[test]
    fn cart_separates_pure_categories() {
        // cats {0,1} -> class 0; cats {2,3} -> class 1.
        let values = vec![0u32, 1, 0, 1, 2, 3, 2, 3];
        let labels_data = vec![0u32, 0, 0, 0, 1, 1, 1, 1];
        let ds = cat_ds(values, 4);
        let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
        let rows: Vec<u32> = (0..8).collect();
        let mut rng = Rng::seed_from_u64(1);
        let c = split_categorical(&ds, 0, &rows, &labels, &cfg(), &mut rng).unwrap();
        match &c.condition {
            Condition::ContainsBitmap { bitmap, .. } => {
                let side0 = bitmap_contains(bitmap, 0);
                assert_eq!(bitmap_contains(bitmap, 1), side0);
                assert_eq!(bitmap_contains(bitmap, 2), !side0);
                assert_eq!(bitmap_contains(bitmap, 3), !side0);
            }
            _ => panic!(),
        }
        // Perfect split: gain = 8 ln 2.
        assert!((c.gain - 8.0 * std::f64::consts::LN_2).abs() < 1e-9);
    }

    #[test]
    fn random_finds_signal_with_enough_trials() {
        let values = vec![0u32, 1, 0, 1, 2, 3, 2, 3];
        let labels_data = vec![0u32, 0, 0, 0, 1, 1, 1, 1];
        let ds = cat_ds(values, 4);
        let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
        let rows: Vec<u32> = (0..8).collect();
        let mut c = cfg();
        c.categorical = CategoricalSplit::Random { trials: 64 };
        let mut rng = Rng::seed_from_u64(2);
        let cand = split_categorical(&ds, 0, &rows, &labels, &c, &mut rng).unwrap();
        assert!((cand.gain - 8.0 * std::f64::consts::LN_2).abs() < 1e-9);
    }

    #[test]
    fn onehot_is_single_category() {
        let values = vec![0u32, 0, 0, 0, 1, 2, 1, 2];
        let labels_data = vec![1u32, 1, 1, 1, 0, 0, 0, 0];
        let ds = cat_ds(values, 3);
        let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
        let rows: Vec<u32> = (0..8).collect();
        let mut c = cfg();
        c.categorical = CategoricalSplit::OneHot;
        let mut rng = Rng::seed_from_u64(3);
        let cand = split_categorical(&ds, 0, &rows, &labels, &c, &mut rng).unwrap();
        match &cand.condition {
            Condition::ContainsBitmap { bitmap, .. } => {
                let members: Vec<u32> = (0..3).filter(|&v| bitmap_contains(bitmap, v)).collect();
                assert_eq!(members, vec![0]); // category 0 vs rest
            }
            _ => panic!(),
        }
    }

    #[test]
    fn onehot_weaker_than_cart_on_two_group_structure() {
        // Classes split across groups {0,1} vs {2,3}: one-hot cannot
        // separate them in a single split; CART can. This is the §5.5
        // mechanism behind XGB/sklearn losing on categorical-heavy data.
        let values = vec![0u32, 1, 0, 1, 2, 3, 2, 3];
        let labels_data = vec![0u32, 0, 0, 0, 1, 1, 1, 1];
        let ds = cat_ds(values, 4);
        let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
        let rows: Vec<u32> = (0..8).collect();
        let mut rng = Rng::seed_from_u64(4);
        let cart = split_categorical(&ds, 0, &rows, &labels, &cfg(), &mut rng).unwrap();
        let mut c1 = cfg();
        c1.categorical = CategoricalSplit::OneHot;
        let onehot = split_categorical(&ds, 0, &rows, &labels, &c1, &mut rng).unwrap();
        assert!(cart.gain > onehot.gain * 1.5, "{} vs {}", cart.gain, onehot.gain);
    }

    #[test]
    fn missing_goes_with_most_frequent() {
        let values = vec![0u32, 0, 0, 1, 1, MISSING_CAT];
        let labels_data = vec![0u32, 0, 0, 1, 1, 0];
        let ds = cat_ds(values, 2);
        let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
        let rows: Vec<u32> = (0..6).collect();
        let mut rng = Rng::seed_from_u64(5);
        let cand = split_categorical(&ds, 0, &rows, &labels, &cfg(), &mut rng).unwrap();
        // Most frequent category is 0; whichever side holds cat 0 receives
        // missing.
        match &cand.condition {
            Condition::ContainsBitmap { bitmap, .. } => {
                assert_eq!(cand.missing_to_positive, bitmap_contains(bitmap, 0));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn boolean_split() {
        let spec = DataSpec { columns: vec![ColumnSpec::boolean("b")] };
        let ds = Dataset::new(
            spec,
            vec![ColumnData::Boolean(vec![1, 1, 1, 0, 0, 0, crate::dataset::MISSING_BOOL])],
        )
        .unwrap();
        let labels_data = vec![1u32, 1, 1, 0, 0, 0, 1];
        let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
        let rows: Vec<u32> = (0..7).collect();
        let cand = split_boolean(&ds, 0, &rows, &labels, &cfg()).unwrap();
        assert!(cand.gain > 0.0);
        assert_eq!(cand.condition, Condition::IsTrue { attr: 0 });
    }

    #[test]
    fn catset_greedy_picks_predictive_tokens() {
        // Token 0 and 1 indicate class 1; tokens 2,3 are noise.
        let spec = DataSpec {
            columns: vec![ColumnSpec::catset(
                "s",
                vec!["t0".into(), "t1".into(), "t2".into(), "t3".into()],
            )],
        };
        let offsets = vec![0u32, 1, 2, 4, 5, 6, 7];
        let values = vec![0u32, 1, 0, 2, 2, 3, 3];
        let ds = Dataset::new(spec, vec![ColumnData::CategoricalSet { offsets, values }])
            .unwrap();
        let labels_data = vec![1u32, 1, 1, 0, 0, 0];
        let labels = Labels::Classification { labels: &labels_data, num_classes: 2 };
        let rows: Vec<u32> = (0..6).collect();
        let cand = split_categorical_set(&ds, 0, &rows, &labels, &cfg()).unwrap();
        match &cand.condition {
            Condition::ContainsSetBitmap { bitmap, .. } => {
                assert!(bitmap_contains(bitmap, 0) || bitmap_contains(bitmap, 1));
                assert!(!bitmap_contains(bitmap, 3));
            }
            _ => panic!(),
        }
        assert!(cand.gain > 0.0);
    }
}
