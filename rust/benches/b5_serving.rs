//! b5: serving-runtime benchmark — the micro-batching path under load.
//!
//! Three families of configurations, all closed-loop (one in-flight
//! request per client — the standard closed-system load model), all
//! recorded to `BENCH_serving.json` so serving performance is tracked
//! across PRs exactly like `BENCH_inference.json` tracks the engine
//! kernels:
//!
//! * `s{rows}_c{clients}` — the PR-3 grid: request-size × concurrency
//!   over one model, single-threaded flush scoring.
//! * `m2_s{rows}_c{clients}` — multi-model: two sessions behind one
//!   registry, clients alternating models, each model coalescing only
//!   its own rows.
//! * `par_s512_c4` / `seq_s512_c4` — large-flush: 512-row requests whose
//!   coalesced flushes fan block spans out across the scoring pool
//!   (`par`, 4 workers) vs the single-threaded baseline (`seq`), so the
//!   parallel-flush speedup is tracked across PRs.
//!
//! Run: cargo bench --bench b5_serving
//!      cargo bench --bench b5_serving -- --requests=500 --out=path.json

use std::sync::Arc;
use std::time::Duration;
use ydf::dataset::synthetic;
use ydf::learner::gbt::GbtConfig;
use ydf::learner::{GradientBoostedTreesLearner, Learner};
use ydf::serving::{Batcher, BatcherConfig, Registry, RowBlock, Session};
use ydf::utils::json::Json;

const REQUEST_ROWS: [usize; 3] = [1, 8, 64];
const CONCURRENCY: [usize; 3] = [1, 4, 16];

struct ComboResult {
    key: String,
    models: usize,
    score_threads: usize,
    request_rows: usize,
    concurrency: usize,
    requests: usize,
    us_per_request: f64,
    requests_per_s: f64,
    rows_per_s: f64,
    mean_batch_rows: f64,
}

fn train_session(seed: u64, trees: usize) -> Session {
    let ds = synthetic::adult_like(4000, seed);
    let mut cfg = GbtConfig::new("income");
    cfg.num_trees = trees;
    cfg.max_depth = 5;
    Session::new(GradientBoostedTreesLearner::new(cfg).train(&ds).unwrap())
}

/// Closed loop over per-client (batcher, prototype-request) lanes — one
/// lane per client, so coalesced batches mix genuinely different rows
/// (a shared prototype would give every flush identical tree paths and
/// flatter-than-real numbers). Client `i` drives lane `i`,
/// `requests_per_client` times.
fn run_closed_loop(lanes: &[(Arc<Batcher>, RowBlock)], requests_per_client: usize) -> f64 {
    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        for (batcher, block) in lanes {
            s.spawn(move || {
                for _ in 0..requests_per_client {
                    let out = batcher
                        .submit(block)
                        .expect("bench load stays under queue capacity")
                        .wait()
                        .expect("batcher serves until dropped");
                    std::hint::black_box(out);
                }
            });
        }
    });
    t0.elapsed().as_secs_f64()
}

fn combo_result(
    key: String,
    models: usize,
    score_threads: usize,
    request_rows: usize,
    concurrency: usize,
    requests_per_client: usize,
    wall: f64,
    batches: u64,
    batched_rows: u64,
) -> ComboResult {
    let total_requests = requests_per_client * concurrency;
    ComboResult {
        key,
        models,
        score_threads,
        request_rows,
        concurrency,
        requests: total_requests,
        us_per_request: wall / total_requests as f64 * 1e6,
        requests_per_s: total_requests as f64 / wall,
        rows_per_s: (total_requests * request_rows) as f64 / wall,
        mean_batch_rows: if batches > 0 { batched_rows as f64 / batches as f64 } else { 0.0 },
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut requests_per_client = 200usize;
    let mut out_path = "BENCH_serving.json".to_string();
    for a in &args {
        if let Some(v) = a.strip_prefix("--requests=") {
            requests_per_client = v.parse().unwrap();
        } else if let Some(v) = a.strip_prefix("--out=") {
            out_path = v.to_string();
        }
    }

    // The b4 workload: adult-like mixed features, QuickScorer-compatible
    // GBT, so b4 and b5 numbers describe the same model family.
    let session = Arc::new(train_session(20230806, 50));
    println!(
        "serving benchmark: engine {}, {} requests/client\n  {:>16} {:>12} {:>11} {:>14} {:>14} {:>12} {:>16}",
        session.engine_name(),
        requests_per_client,
        "combo",
        "request_rows",
        "concurrency",
        "us/request",
        "requests/s",
        "rows/s",
        "mean batch rows",
    );
    let mut results: Vec<ComboResult> = Vec::new();
    let mut report = |r: &ComboResult| {
        println!(
            "  {:>16} {:>12} {:>11} {:>14.2} {:>14.0} {:>12.0} {:>16.1}",
            r.key,
            r.request_rows,
            r.concurrency,
            r.us_per_request,
            r.requests_per_s,
            r.rows_per_s,
            r.mean_batch_rows,
        );
    };

    // Family 1: the single-model request-size × concurrency grid
    // (single-threaded flushes — the PR-3 baseline numbers).
    for &request_rows in &REQUEST_ROWS {
        for &concurrency in &CONCURRENCY {
            let batcher = Arc::new(Batcher::new(
                Arc::clone(&session),
                BatcherConfig {
                    // Adaptive drain: coalesce exactly the backlog that
                    // accumulates while the previous batch scores.
                    max_delay: Duration::ZERO,
                    score_threads: 1,
                    ..Default::default()
                },
            ));
            let lanes: Vec<(Arc<Batcher>, RowBlock)> = (0..concurrency)
                .map(|client| {
                    (Arc::clone(&batcher), request_block(&session, request_rows, client))
                })
                .collect();
            let wall = run_closed_loop(&lanes, requests_per_client);
            let snap = batcher.stats().snapshot();
            let r = combo_result(
                format!("s{request_rows}_c{concurrency}"),
                1,
                1,
                request_rows,
                concurrency,
                requests_per_client,
                wall,
                snap.batches,
                snap.batched_rows,
            );
            report(&r);
            results.push(r);
        }
    }

    // Family 2: two models behind one registry, clients alternating —
    // the multi-model serving dimension.
    {
        let mut registry = Registry::new(BatcherConfig {
            max_delay: Duration::ZERO,
            score_threads: 1,
            ..Default::default()
        });
        registry.register("m0", train_session(20230806, 50)).unwrap();
        registry.register("m1", train_session(7151, 50)).unwrap();
        for &concurrency in &[4usize, 16] {
            let request_rows = 8usize;
            // One lane per client, alternating models, rows varied per
            // client.
            let lanes: Vec<(Arc<Batcher>, RowBlock)> = (0..concurrency)
                .map(|client| {
                    let e = &registry.entries()[client % registry.len()];
                    (Arc::clone(e.batcher()), request_block(e.session(), request_rows, client))
                })
                .collect();
            // The registry's stats persist across concurrency runs;
            // report this run's delta.
            let base: Vec<(u64, u64)> = registry
                .entries()
                .iter()
                .map(|e| {
                    let s = e.stats().snapshot();
                    (s.batches, s.batched_rows)
                })
                .collect();
            let wall = run_closed_loop(&lanes, requests_per_client);
            let (mut batches, mut batched_rows) = (0u64, 0u64);
            for (e, (b0, r0)) in registry.entries().iter().zip(&base) {
                let s = e.stats().snapshot();
                batches += s.batches - b0;
                batched_rows += s.batched_rows - r0;
            }
            let r = combo_result(
                format!("m2_s{request_rows}_c{concurrency}"),
                2,
                1,
                request_rows,
                concurrency,
                requests_per_client,
                wall,
                batches,
                batched_rows,
            );
            report(&r);
            results.push(r);
        }
    }

    // Family 3: large coalesced flushes, parallel-scored vs serial —
    // the `predict_into`-style fan-out inside a flush.
    for (key, score_threads) in [("seq_s512_c4", 1usize), ("par_s512_c4", 4usize)] {
        let batcher = Arc::new(Batcher::new(
            Arc::clone(&session),
            BatcherConfig {
                max_delay: Duration::ZERO,
                score_threads,
                max_queue_rows: 8 * 512,
                ..Default::default()
            },
        ));
        let lanes: Vec<(Arc<Batcher>, RowBlock)> = (0..4)
            .map(|client| (Arc::clone(&batcher), request_block(&session, 512, client)))
            .collect();
        // Fewer, heavier requests: same row volume as ~64-row combos.
        let heavy_requests = (requests_per_client / 8).max(10);
        let wall = run_closed_loop(&lanes, heavy_requests);
        let snap = batcher.stats().snapshot();
        let r = combo_result(
            key.to_string(),
            1,
            score_threads,
            512,
            4,
            heavy_requests,
            wall,
            snap.batches,
            snap.batched_rows,
        );
        report(&r);
        results.push(r);
    }

    let mut combos = Json::obj();
    for r in &results {
        let mut cj = Json::obj();
        cj.set("models", Json::Num(r.models as f64))
            .set("score_threads", Json::Num(r.score_threads as f64))
            .set("request_rows", Json::Num(r.request_rows as f64))
            .set("concurrency", Json::Num(r.concurrency as f64))
            .set("requests", Json::Num(r.requests as f64))
            .set("us_per_request", Json::Num(r.us_per_request))
            .set("requests_per_s", Json::Num(r.requests_per_s))
            .set("rows_per_s", Json::Num(r.rows_per_s))
            .set("mean_batch_rows", Json::Num(r.mean_batch_rows));
        combos.set(&r.key, cj);
    }
    let mut j = Json::obj();
    j.set("engine", Json::Str(session.engine_name()))
        .set("requests_per_client", Json::Num(requests_per_client as f64))
        .set("block_size", Json::Num(ydf::inference::BLOCK_SIZE as f64))
        .set("combos", combos);
    match std::fs::write(&out_path, j.to_string_pretty()) {
        Ok(()) => eprintln!("wrote {out_path}"),
        Err(e) => eprintln!("cannot write {out_path}: {e}"),
    }
}

/// Builds one request of `rows` rows from dataset-like feature values,
/// varied per lane so coalesced batches are not degenerate.
fn request_block(session: &Session, rows: usize, lane: usize) -> RowBlock {
    let workclasses = ["Private", "Self-emp-inc", "Federal-gov", "Local-gov"];
    let educations = ["HS-grad", "Bachelors", "Masters", "Doctorate"];
    let mut block = session.new_block();
    for i in 0..rows {
        let k = lane * 31 + i;
        let row = Json::parse(&format!(
            r#"{{"age": {}, "hours_per_week": {}, "workclass": "{}",
                "education": "{}", "capital_gain": {}}}"#,
            18 + k % 60,
            20 + (k * 7) % 50,
            workclasses[k % workclasses.len()],
            educations[(k / 2) % educations.len()],
            (k % 9) * 700,
        ))
        .unwrap();
        session.decode_row(&mut block, &row).unwrap();
    }
    block
}
