//! Dataspec: per-column semantics, statistics and dictionaries, plus the
//! automated semantic-inference heuristics of §3.4.
//!
//! "Any operation that can be automated should be automated, the user should
//! be made aware of the automation, and should be given control over it"
//! (§2.1): `infer` produces the spec from raw string columns, `describe`
//! renders the human-readable report of what was decided, and callers may
//! override any column before building the dataset.

use crate::utils::histogram::TextHistogram;
use crate::utils::json::Json;
use crate::utils::stats::Moments;
use std::collections::HashMap;

/// Model-agnostic feature semantics (§3.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FeatureSemantic {
    /// Total ordering and scale significance (quantities, counts).
    Numerical,
    /// Discrete space without order (types, colors).
    Categorical,
    /// True/false.
    Boolean,
    /// A value is a *set* of categories (e.g. tokenized text).
    CategoricalSet,
}

impl FeatureSemantic {
    pub fn name(&self) -> &'static str {
        match self {
            FeatureSemantic::Numerical => "NUMERICAL",
            FeatureSemantic::Categorical => "CATEGORICAL",
            FeatureSemantic::Boolean => "BOOLEAN",
            FeatureSemantic::CategoricalSet => "CATEGORICAL_SET",
        }
    }

    pub fn from_name(s: &str) -> Option<FeatureSemantic> {
        match s {
            "NUMERICAL" => Some(FeatureSemantic::Numerical),
            "CATEGORICAL" => Some(FeatureSemantic::Categorical),
            "BOOLEAN" => Some(FeatureSemantic::Boolean),
            "CATEGORICAL_SET" => Some(FeatureSemantic::CategoricalSet),
            _ => None,
        }
    }
}

/// Numerical column statistics, used for reports and global imputation.
#[derive(Clone, Debug, Default)]
pub struct NumericalStats {
    pub mean: f64,
    pub min: f64,
    pub max: f64,
    pub std: f64,
}

/// Per-column specification.
#[derive(Clone, Debug)]
pub struct ColumnSpec {
    pub name: String,
    pub semantic: FeatureSemantic,
    /// Dictionary for categorical / categorical-set columns; index = code.
    pub dictionary: Vec<String>,
    /// Occurrence count per dictionary entry (same length as `dictionary`).
    pub dict_counts: Vec<u64>,
    /// Count of out-of-dictionary items observed during inference.
    pub ood_items: u64,
    pub num_stats: NumericalStats,
    /// Number of missing (non-available) values observed.
    pub missing_count: u64,
    /// True if the user set the semantic explicitly rather than relying on
    /// automated inference (shown in reports as `manually-defined`).
    pub manually_defined: bool,
}

impl ColumnSpec {
    pub fn numerical(name: &str) -> ColumnSpec {
        ColumnSpec {
            name: name.to_string(),
            semantic: FeatureSemantic::Numerical,
            dictionary: vec![],
            dict_counts: vec![],
            ood_items: 0,
            num_stats: NumericalStats::default(),
            missing_count: 0,
            manually_defined: false,
        }
    }

    pub fn categorical(name: &str, dictionary: Vec<String>) -> ColumnSpec {
        let n = dictionary.len();
        ColumnSpec {
            name: name.to_string(),
            semantic: FeatureSemantic::Categorical,
            dictionary,
            dict_counts: vec![0; n],
            ood_items: 0,
            num_stats: NumericalStats::default(),
            missing_count: 0,
            manually_defined: false,
        }
    }

    pub fn boolean(name: &str) -> ColumnSpec {
        ColumnSpec { semantic: FeatureSemantic::Boolean, ..ColumnSpec::numerical(name) }
    }

    pub fn catset(name: &str, dictionary: Vec<String>) -> ColumnSpec {
        ColumnSpec {
            semantic: FeatureSemantic::CategoricalSet,
            ..ColumnSpec::categorical(name, dictionary)
        }
    }

    pub fn vocab_size(&self) -> usize {
        self.dictionary.len()
    }

    /// Dictionary index of a category name.
    pub fn category_index(&self, value: &str) -> Option<u32> {
        self.dictionary.iter().position(|d| d == value).map(|i| i as u32)
    }

    /// Most frequent category (global imputation value for categoricals).
    pub fn most_frequent_category(&self) -> Option<u32> {
        self.dict_counts
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .map(|(i, _)| i as u32)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("name", Json::Str(self.name.clone()))
            .set("semantic", Json::Str(self.semantic.name().into()))
            .set(
                "dictionary",
                Json::Arr(self.dictionary.iter().map(|s| Json::Str(s.clone())).collect()),
            )
            .set(
                "dict_counts",
                Json::Arr(self.dict_counts.iter().map(|&c| Json::Num(c as f64)).collect()),
            )
            .set("ood_items", Json::Num(self.ood_items as f64))
            .set("mean", Json::Num(self.num_stats.mean))
            .set("min", Json::Num(self.num_stats.min))
            .set("max", Json::Num(self.num_stats.max))
            .set("std", Json::Num(self.num_stats.std))
            .set("missing_count", Json::Num(self.missing_count as f64))
            .set("manually_defined", Json::Bool(self.manually_defined));
        j
    }

    pub fn from_json(j: &Json) -> Result<ColumnSpec, String> {
        let semantic_name = j.req_str("semantic")?;
        let semantic = FeatureSemantic::from_name(semantic_name)
            .ok_or_else(|| format!("unknown feature semantic '{semantic_name}'"))?;
        let dictionary: Vec<String> = j
            .req_arr("dictionary")?
            .iter()
            .map(|v| v.as_str().unwrap_or_default().to_string())
            .collect();
        let dict_counts: Vec<u64> = j
            .req_arr("dict_counts")?
            .iter()
            .map(|v| v.as_f64().unwrap_or(0.0) as u64)
            .collect();
        Ok(ColumnSpec {
            name: j.req_str("name")?.to_string(),
            semantic,
            dictionary,
            dict_counts,
            ood_items: j.req_f64("ood_items")? as u64,
            num_stats: NumericalStats {
                mean: j.req_f64("mean")?,
                min: j.req_f64("min")?,
                max: j.req_f64("max")?,
                std: j.req_f64("std")?,
            },
            missing_count: j.req_f64("missing_count")? as u64,
            manually_defined: j.get("manually_defined").and_then(|v| v.as_bool()).unwrap_or(false),
        })
    }
}

/// Dataset specification: the ordered list of columns.
#[derive(Clone, Debug)]
pub struct DataSpec {
    pub columns: Vec<ColumnSpec>,
}

impl DataSpec {
    pub fn column(&self, name: &str) -> Option<&ColumnSpec> {
        self.columns.iter().find(|c| c.name == name)
    }

    pub fn column_index(&self, name: &str) -> Option<usize> {
        self.columns.iter().position(|c| c.name == name)
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("columns", Json::Arr(self.columns.iter().map(|c| c.to_json()).collect()));
        j
    }

    pub fn from_json(j: &Json) -> Result<DataSpec, String> {
        let columns = j
            .req_arr("columns")?
            .iter()
            .map(ColumnSpec::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(DataSpec { columns })
    }

    /// Renders the `show_dataspec` report (Appendix B.1 format).
    pub fn describe(&self, num_rows: usize) -> String {
        let mut by_sem: HashMap<&'static str, usize> = HashMap::new();
        for c in &self.columns {
            *by_sem.entry(c.semantic.name()).or_insert(0) += 1;
        }
        let mut out = format!(
            "Number of records: {}\nNumber of columns: {}\n\nNumber of columns by type:\n",
            num_rows,
            self.columns.len()
        );
        let mut sems: Vec<_> = by_sem.iter().collect();
        sems.sort();
        for (sem, count) in sems {
            out.push_str(&format!(
                "    {}: {} ({:.0}%)\n",
                sem,
                count,
                100.0 * *count as f64 / self.columns.len().max(1) as f64
            ));
        }
        out.push_str("\nColumns:\n");
        for (i, c) in self.columns.iter().enumerate() {
            match c.semantic {
                FeatureSemantic::Categorical | FeatureSemantic::CategoricalSet => {
                    let most = c
                        .most_frequent_category()
                        .map(|m| {
                            format!(
                                "most-frequent:\"{}\" {} ({:.4}%)",
                                c.dictionary[m as usize],
                                c.dict_counts[m as usize],
                                100.0 * c.dict_counts[m as usize] as f64 / num_rows.max(1) as f64
                            )
                        })
                        .unwrap_or_default();
                    out.push_str(&format!(
                        "    {}: \"{}\" {} has-dict vocab-size:{} {}-ood-items {}{}\n",
                        i,
                        c.name,
                        c.semantic.name(),
                        c.vocab_size(),
                        if c.ood_items == 0 { "zero".to_string() } else { c.ood_items.to_string() },
                        most,
                        if c.manually_defined { " manually-defined" } else { "" },
                    ));
                }
                FeatureSemantic::Numerical => {
                    out.push_str(&format!(
                        "    {}: \"{}\" NUMERICAL mean:{:.4} min:{} max:{} sd:{:.4}{}{}\n",
                        i,
                        c.name,
                        c.num_stats.mean,
                        c.num_stats.min,
                        c.num_stats.max,
                        c.num_stats.std,
                        if c.missing_count > 0 {
                            format!(" nas:{}", c.missing_count)
                        } else {
                            String::new()
                        },
                        if c.manually_defined { " manually-defined" } else { "" },
                    ));
                }
                FeatureSemantic::Boolean => {
                    out.push_str(&format!("    {}: \"{}\" BOOLEAN\n", i, c.name));
                }
            }
        }
        out.push_str(
            "\nTerminology:\n    nas: Number of non-available (i.e. missing) values.\n    \
             ood: Out of dictionary.\n    manually-defined: Attribute which type is manually \
             defined by the user i.e. the type was not automatically inferred.\n    has-dict: \
             The attribute is attached to a string dictionary.\n    vocab-size: Number of \
             unique values.\n",
        );
        out
    }
}

/// A raw (string) column prior to semantic inference.
pub struct RawColumn {
    pub name: String,
    pub values: Vec<Option<String>>, // None = missing cell
}

/// Options controlling automated semantic inference (§3.4 heuristics). The
/// defaults mirror YDF's: numbers become NUMERICAL unless their unique-value
/// count is tiny; strings become CATEGORICAL; rare categories are pruned to
/// out-of-dictionary.
#[derive(Clone, Debug)]
pub struct InferenceOptions {
    /// A parsed-as-number column with at most this many distinct values is
    /// treated as CATEGORICAL (e.g. {1, 2, 3} class codes).
    pub max_unique_for_numerical_as_categorical: usize,
    /// Maximum dictionary size; less frequent values become OOD.
    pub max_vocab_size: usize,
    /// Minimum occurrences for a dictionary entry.
    pub min_vocab_frequency: u64,
    /// Columns whose semantic the user forces.
    pub overrides: Vec<(String, FeatureSemantic)>,
}

impl Default for InferenceOptions {
    fn default() -> Self {
        InferenceOptions {
            max_unique_for_numerical_as_categorical: 5,
            max_vocab_size: 2000,
            min_vocab_frequency: 1,
            overrides: vec![],
        }
    }
}

/// Result of dataspec inference: spec + encoded columns + user-facing notes
/// about what was automated (§2.1: "the user should be made aware").
pub struct InferredData {
    pub spec: DataSpec,
    pub columns: Vec<super::ColumnData>,
    pub notes: Vec<String>,
}

/// Infers semantics and encodes raw columns into typed storage.
pub fn infer_dataspec(raw: &[RawColumn], options: &InferenceOptions) -> Result<InferredData, String> {
    let mut specs = Vec::with_capacity(raw.len());
    let mut datas = Vec::with_capacity(raw.len());
    let mut notes = Vec::new();
    for col in raw {
        let forced = options
            .overrides
            .iter()
            .find(|(n, _)| n == &col.name)
            .map(|(_, s)| *s);
        let semantic = forced.unwrap_or_else(|| guess_semantic(col, options));
        let (mut spec, data) = encode_column(col, semantic, options)?;
        spec.manually_defined = forced.is_some();
        if forced.is_none() {
            notes.push(format!(
                "column \"{}\": automatically detected semantic {} ({}). Override with \
                 InferenceOptions::overrides if incorrect.",
                col.name,
                semantic.name(),
                semantic_reason(col, semantic)
            ));
        }
        specs.push(spec);
        datas.push(data);
    }
    Ok(InferredData { spec: DataSpec { columns: specs }, columns: datas, notes })
}

fn is_number(s: &str) -> bool {
    s.trim().parse::<f64>().map(|x| x.is_finite()).unwrap_or(false)
}

fn is_bool_token(s: &str) -> bool {
    matches!(s.trim().to_ascii_lowercase().as_str(), "true" | "false")
}

fn semantic_reason(col: &RawColumn, sem: FeatureSemantic) -> &'static str {
    let _ = col;
    match sem {
        FeatureSemantic::Numerical => "most values parse as numbers with many unique values",
        FeatureSemantic::Categorical => "non-numeric strings or few unique values",
        FeatureSemantic::Boolean => "all values are true/false",
        FeatureSemantic::CategoricalSet => "values are whitespace-separated token sets",
    }
}

fn guess_semantic(col: &RawColumn, options: &InferenceOptions) -> FeatureSemantic {
    let present: Vec<&str> = col.values.iter().flatten().map(|s| s.as_str()).collect();
    if present.is_empty() {
        return FeatureSemantic::Numerical;
    }
    if present.iter().all(|s| is_bool_token(s)) {
        return FeatureSemantic::Boolean;
    }
    let numeric = present.iter().filter(|s| is_number(s)).count();
    let numeric_frac = numeric as f64 / present.len() as f64;
    if numeric_frac >= 0.999 {
        let mut unique: Vec<&str> = present.clone();
        unique.sort_unstable();
        unique.dedup();
        if unique.len() <= options.max_unique_for_numerical_as_categorical {
            return FeatureSemantic::Categorical;
        }
        return FeatureSemantic::Numerical;
    }
    FeatureSemantic::Categorical
}

fn encode_column(
    col: &RawColumn,
    semantic: FeatureSemantic,
    options: &InferenceOptions,
) -> Result<(ColumnSpec, super::ColumnData), String> {
    use super::{ColumnData, MISSING_BOOL, MISSING_CAT};
    match semantic {
        FeatureSemantic::Numerical => {
            let mut spec = ColumnSpec::numerical(&col.name);
            let mut m = Moments::new();
            let mut values = Vec::with_capacity(col.values.len());
            for v in &col.values {
                match v {
                    None => {
                        spec.missing_count += 1;
                        values.push(f32::NAN);
                    }
                    Some(s) => {
                        let x: f64 = s.trim().parse().map_err(|_| {
                            format!(
                                "column \"{}\" is declared NUMERICAL but the value \"{}\" does \
                                 not parse as a number. Possible solutions: (1) declare the \
                                 column CATEGORICAL, or (2) clean the dataset.",
                                col.name, s
                            )
                        })?;
                        m.add(x);
                        values.push(x as f32);
                    }
                }
            }
            if m.count() > 0 {
                spec.num_stats =
                    NumericalStats { mean: m.mean(), min: m.min(), max: m.max(), std: m.std() };
            }
            Ok((spec, ColumnData::Numerical(values)))
        }
        FeatureSemantic::Boolean => {
            let mut spec = ColumnSpec::boolean(&col.name);
            let mut values = Vec::with_capacity(col.values.len());
            for v in &col.values {
                match v.as_deref().map(|s| s.trim().to_ascii_lowercase()) {
                    None => {
                        spec.missing_count += 1;
                        values.push(MISSING_BOOL);
                    }
                    Some(s) if s == "true" || s == "1" => values.push(1),
                    Some(s) if s == "false" || s == "0" => values.push(0),
                    Some(s) => {
                        return Err(format!(
                            "column \"{}\" is declared BOOLEAN but contains \"{s}\".",
                            col.name
                        ))
                    }
                }
            }
            Ok((spec, ColumnData::Boolean(values)))
        }
        FeatureSemantic::Categorical => {
            // Build frequency-ordered dictionary.
            let mut counts: HashMap<&str, u64> = HashMap::new();
            for v in col.values.iter().flatten() {
                *counts.entry(v.as_str()).or_insert(0) += 1;
            }
            let mut entries: Vec<(&str, u64)> = counts.into_iter().collect();
            // Sort by (desc count, asc name) for determinism.
            entries.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(b.0)));
            let mut ood = 0u64;
            let mut kept = Vec::new();
            for (i, (name, c)) in entries.iter().enumerate() {
                if i < options.max_vocab_size && *c >= options.min_vocab_frequency {
                    kept.push((*name, *c));
                } else {
                    ood += *c;
                }
            }
            let dictionary: Vec<String> = kept.iter().map(|(n, _)| n.to_string()).collect();
            let dict_counts: Vec<u64> = kept.iter().map(|(_, c)| *c).collect();
            let lookup: HashMap<&str, u32> =
                kept.iter().enumerate().map(|(i, (n, _))| (*n, i as u32)).collect();
            let mut spec = ColumnSpec::categorical(&col.name, dictionary);
            spec.dict_counts = dict_counts;
            spec.ood_items = ood;
            let mut values = Vec::with_capacity(col.values.len());
            for v in &col.values {
                match v {
                    None => {
                        spec.missing_count += 1;
                        values.push(MISSING_CAT);
                    }
                    Some(s) => {
                        // OOD values map to missing (YDF maps them to a
                        // reserved OOD bucket; missing is the closest
                        // behaviour without a dedicated code).
                        values.push(*lookup.get(s.as_str()).unwrap_or(&MISSING_CAT));
                    }
                }
            }
            Ok((spec, ColumnData::Categorical(values)))
        }
        FeatureSemantic::CategoricalSet => {
            // Values are whitespace-separated token lists.
            let mut counts: HashMap<String, u64> = HashMap::new();
            for v in col.values.iter().flatten() {
                for tok in v.split_whitespace() {
                    *counts.entry(tok.to_string()).or_insert(0) += 1;
                }
            }
            let mut entries: Vec<(String, u64)> = counts.into_iter().collect();
            entries.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
            entries.truncate(options.max_vocab_size);
            let dictionary: Vec<String> = entries.iter().map(|(n, _)| n.clone()).collect();
            let dict_counts: Vec<u64> = entries.iter().map(|(_, c)| *c).collect();
            let lookup: HashMap<String, u32> = dictionary
                .iter()
                .enumerate()
                .map(|(i, n)| (n.clone(), i as u32))
                .collect();
            let mut spec = ColumnSpec::catset(&col.name, dictionary);
            spec.dict_counts = dict_counts;
            let mut offsets = vec![0u32];
            let mut values = Vec::new();
            for v in &col.values {
                match v {
                    None => {
                        spec.missing_count += 1;
                        values.push(MISSING_CAT);
                    }
                    Some(s) => {
                        for tok in s.split_whitespace() {
                            if let Some(&code) = lookup.get(tok) {
                                values.push(code);
                            }
                        }
                    }
                }
                offsets.push(values.len() as u32);
            }
            Ok((spec, ColumnData::CategoricalSet { offsets, values }))
        }
    }
}

/// Safety-of-use check (§2.2): called by classification learners. If the
/// label column looks like a regression target, returns the well-written
/// error of Table 1(b) / §2.2 rather than training a nonsensical model.
pub fn check_classification_label(
    spec: &ColumnSpec,
    num_rows: usize,
    disable_error: bool,
) -> Result<(), String> {
    if spec.semantic == FeatureSemantic::Numerical {
        return Err(format!(
            "Classification training requires a CATEGORICAL label, however, the label column \
             \"{}\" has NUMERICAL semantics. Possible solutions: (1) Configure the training as \
             a regression with task=REGRESSION, or (2) force the label column to CATEGORICAL in \
             the dataspec.",
            spec.name
        ));
    }
    let vocab = spec.vocab_size();
    let numeric_looking = spec
        .dictionary
        .iter()
        .filter(|d| d.trim().parse::<f64>().is_ok())
        .count();
    if !disable_error
        && vocab > 50
        && num_rows > 0
        && numeric_looking as f64 >= 0.99 * vocab as f64
    {
        return Err(format!(
            "The classification label column \"{}\" looks like a regression column ({} unique \
             values for {} examples, {:.0}% of the values look like numbers). Solutions: (1) \
             Configure the training as a regression with task=REGRESSION, or (2) disable the \
             error with disable_error.classification_look_like_regression=true.",
            spec.name,
            vocab,
            num_rows,
            100.0 * numeric_looking as f64 / vocab as f64
        ));
    }
    Ok(())
}

/// Renders the distribution of a numerical column (report helper).
pub fn render_numerical_histogram(values: &[f32], bins: usize) -> String {
    let mut h = TextHistogram::new();
    h.extend(values.iter().filter(|v| !v.is_nan()).map(|&v| v as f64));
    h.render(bins, 10)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn raw(name: &str, vals: &[&str]) -> RawColumn {
        RawColumn {
            name: name.into(),
            values: vals
                .iter()
                .map(|s| if s.is_empty() { None } else { Some(s.to_string()) })
                .collect(),
        }
    }

    #[test]
    fn infers_numerical() {
        let r = infer_dataspec(
            &[raw("age", &["44", "20", "40", "30", "67", "18", "51.5"])],
            &InferenceOptions::default(),
        )
        .unwrap();
        assert_eq!(r.spec.columns[0].semantic, FeatureSemantic::Numerical);
        assert!(r.spec.columns[0].num_stats.max > 67.0 - 1e-6);
    }

    #[test]
    fn infers_categorical_strings() {
        let r = infer_dataspec(
            &[raw("workclass", &["Private", "Private", "Self-emp", "Private"])],
            &InferenceOptions::default(),
        )
        .unwrap();
        let c = &r.spec.columns[0];
        assert_eq!(c.semantic, FeatureSemantic::Categorical);
        assert_eq!(c.dictionary[0], "Private"); // most frequent first
        assert_eq!(c.dict_counts[0], 3);
    }

    #[test]
    fn numeric_with_few_uniques_becomes_categorical() {
        let r = infer_dataspec(
            &[raw("code", &["1", "2", "1", "2", "3", "1"])],
            &InferenceOptions::default(),
        )
        .unwrap();
        assert_eq!(r.spec.columns[0].semantic, FeatureSemantic::Categorical);
    }

    #[test]
    fn infers_boolean() {
        let r = infer_dataspec(
            &[raw("flag", &["true", "false", "true"])],
            &InferenceOptions::default(),
        )
        .unwrap();
        assert_eq!(r.spec.columns[0].semantic, FeatureSemantic::Boolean);
    }

    #[test]
    fn missing_values_counted() {
        let r = infer_dataspec(
            &[raw("x", &["1", "", "3", "", "5", "6"])],
            &InferenceOptions::default(),
        )
        .unwrap();
        assert_eq!(r.spec.columns[0].missing_count, 2);
        let col = &r.columns[0];
        assert!(col.is_missing(1) && col.is_missing(3));
    }

    #[test]
    fn override_forces_semantic() {
        let mut opts = InferenceOptions::default();
        opts.overrides.push(("zip".into(), FeatureSemantic::Categorical));
        let r = infer_dataspec(
            &[raw("zip", &["94103", "10001", "60601", "94103", "73301", "94110"])],
            &opts,
        )
        .unwrap();
        assert_eq!(r.spec.columns[0].semantic, FeatureSemantic::Categorical);
        assert!(r.spec.columns[0].manually_defined);
    }

    #[test]
    fn classification_label_guard() {
        // A numeric-looking high-cardinality label triggers the §2.2 error.
        let vals: Vec<String> = (0..100).map(|i| format!("{}", i * 3 + 1)).collect();
        let refs: Vec<&str> = vals.iter().map(|s| s.as_str()).collect();
        let mut opts = InferenceOptions::default();
        opts.overrides.push(("revenue".into(), FeatureSemantic::Categorical));
        let r = infer_dataspec(&[raw("revenue", &refs)], &opts).unwrap();
        let err =
            check_classification_label(&r.spec.columns[0], 100, false).unwrap_err();
        assert!(err.contains("looks like a regression column"), "{err}");
        // And can be explicitly disabled (§2.2: option to ignore).
        assert!(check_classification_label(&r.spec.columns[0], 100, true).is_ok());
    }

    #[test]
    fn dataspec_json_roundtrip() {
        let r = infer_dataspec(
            &[
                raw("age", &["1", "2", "3", "4", "5", "6", "7"]),
                raw("color", &["red", "blue", "red"]),
            ],
            &InferenceOptions::default(),
        )
        .unwrap();
        let j = r.spec.to_json();
        let back = DataSpec::from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(back.columns.len(), 2);
        assert_eq!(back.columns[1].dictionary, r.spec.columns[1].dictionary);
        assert_eq!(back.columns[0].semantic, FeatureSemantic::Numerical);
    }

    #[test]
    fn describe_mentions_counts() {
        let r = infer_dataspec(
            &[raw("color", &["red", "blue", "red", "green"])],
            &InferenceOptions::default(),
        )
        .unwrap();
        let report = r.spec.describe(4);
        assert!(report.contains("Number of records: 4"));
        assert!(report.contains("CATEGORICAL"));
        assert!(report.contains("most-frequent:\"red\""));
    }

    #[test]
    fn catset_tokenization() {
        let r = infer_dataspec(
            &[RawColumn {
                name: "text".into(),
                values: vec![Some("hello world".into()), Some("world".into()), None],
            }],
            &InferenceOptions {
                overrides: vec![("text".into(), FeatureSemantic::CategoricalSet)],
                ..Default::default()
            },
        )
        .unwrap();
        let col = &r.columns[0];
        assert_eq!(col.set_values(0).unwrap().len(), 2);
        assert_eq!(col.set_values(1).unwrap().len(), 1);
        assert!(col.is_missing(2));
    }
}
