//! Model (de)serialization: a versioned JSON format with full backwards
//! compatibility (§3.11 — "models trained in 2018 are still usable today").
//!
//! The format version is embedded in every file; loaders accept all
//! versions ≤ current. `rust/tests/backcompat.rs` pins a v1 fixture.

use super::forest::{GbtLoss, GradientBoostedTreesModel, RandomForestModel};
use super::linear::{DenseEncoding, LinearModel};
use super::tree::DecisionTree;
use super::{Model, SelfEvaluation, Task};
use crate::dataset::DataSpec;
use crate::utils::json::Json;
use std::path::Path;

/// Current model format version. Bump only with an accompanying loader
/// branch — old files must load forever.
pub const MODEL_FORMAT_VERSION: u32 = 1;

/// Serializes any model to its JSON text form.
pub fn model_to_string(model: &dyn Model) -> String {
    model.to_json().to_string_pretty()
}

/// Saves a model to a file.
pub fn save_model(model: &dyn Model, path: &Path) -> Result<(), String> {
    std::fs::write(path, model_to_string(model))
        .map_err(|e| format!("cannot write model file {}: {e}", path.display()))
}

/// Loads a model from a JSON text string, dispatching on `model_type`.
pub fn model_from_string(text: &str) -> Result<Box<dyn Model>, String> {
    let j = Json::parse(text).map_err(|e| format!("invalid model file: {e}"))?;
    let version = j.req_usize("format_version")? as u32;
    if version > MODEL_FORMAT_VERSION {
        return Err(format!(
            "model format version {version} is newer than this library supports \
             ({MODEL_FORMAT_VERSION}). Upgrade the library to load this model."
        ));
    }
    let task = match j.req_str("task")? {
        "CLASSIFICATION" => Task::Classification,
        "REGRESSION" => Task::Regression,
        t => return Err(format!("unknown task '{t}'")),
    };
    let spec = DataSpec::from_json(j.req("spec")?)?;
    let label_col = j.req_usize("label_col")?;
    let parse_trees = |j: &Json| -> Result<Vec<DecisionTree>, String> {
        j.req_arr("trees")?.iter().map(DecisionTree::from_json).collect()
    };
    match j.req_str("model_type")? {
        "RANDOM_FOREST" => {
            let oob_evaluation = j.get("self_evaluation").map(|ej| SelfEvaluation {
                metric: ej.req_str("metric").unwrap_or("oob").to_string(),
                value: ej.req_f64("value").unwrap_or(0.0),
                num_examples: ej.req_f64("num_examples").unwrap_or(0.0) as u64,
            });
            Ok(Box::new(RandomForestModel {
                spec,
                label_col,
                task,
                trees: parse_trees(&j)?,
                winner_take_all: j
                    .get("winner_take_all")
                    .and_then(|v| v.as_bool())
                    .unwrap_or(false),
                oob_evaluation,
            }))
        }
        "GRADIENT_BOOSTED_TREES" => {
            let loss_name = j.req_str("loss")?;
            let loss = GbtLoss::from_name(loss_name)
                .ok_or_else(|| format!("unknown GBT loss '{loss_name}'"))?;
            Ok(Box::new(GradientBoostedTreesModel {
                spec,
                label_col,
                task,
                loss,
                trees: parse_trees(&j)?,
                trees_per_iter: j.req_usize("trees_per_iter")?,
                initial_predictions: j
                    .req_arr("initial_predictions")?
                    .iter()
                    .map(|v| v.as_f64().unwrap_or(0.0))
                    .collect(),
                validation_loss: j.get("validation_loss").and_then(|v| v.as_f64()),
                self_eval: None,
            }))
        }
        "LINEAR" => Ok(Box::new(LinearModel {
            spec,
            label_col,
            task,
            encoding: DenseEncoding::from_json(j.req("encoding")?)?,
            weights: j
                .req_arr("weights")?
                .iter()
                .map(|wj| {
                    wj.as_arr()
                        .map(|a| a.iter().map(|v| v.as_f64().unwrap_or(0.0) as f32).collect())
                        .ok_or_else(|| "weights rows must be arrays".to_string())
                })
                .collect::<Result<Vec<Vec<f32>>, String>>()?,
            bias: j
                .req_arr("bias")?
                .iter()
                .map(|v| v.as_f64().unwrap_or(0.0) as f32)
                .collect(),
            self_eval: None,
        })),
        t => Err(format!(
            "unknown model type '{t}'. This library supports RANDOM_FOREST, \
             GRADIENT_BOOSTED_TREES and LINEAR."
        )),
    }
}

/// Loads a model from a file. Sniffs the first bytes: a compiled-forest
/// artifact (magic `"YDFC"`, see `inference::compiled`) opens via mmap as
/// a [`crate::inference::compiled::CompiledModel`]; anything else is
/// parsed as the JSON model format. Callers — the CLI, the serving
/// `Session` — therefore accept `.bin` artifacts wherever they accept
/// JSON models.
pub fn load_model(path: &Path) -> Result<Box<dyn Model>, String> {
    let mut magic = [0u8; 4];
    let is_artifact = std::fs::File::open(path)
        .and_then(|mut f| std::io::Read::read_exact(&mut f, &mut magic))
        .map(|_| magic == crate::inference::compiled::ARTIFACT_MAGIC)
        .unwrap_or(false);
    if is_artifact {
        return crate::inference::compiled::CompiledModel::open(path)
            .map(|m| Box::new(m) as Box<dyn Model>);
    }
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("cannot read model file {}: {e}", path.display()))?;
    model_from_string(&text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::dataspec::ColumnSpec;
    use crate::dataset::AttrValue;
    use crate::model::tree::{Condition, Node};

    fn sample_rf() -> RandomForestModel {
        let spec = DataSpec {
            columns: vec![
                ColumnSpec::numerical("x"),
                ColumnSpec::categorical("y", vec!["a".into(), "b".into()]),
            ],
        };
        RandomForestModel {
            spec,
            label_col: 1,
            task: Task::Classification,
            trees: vec![DecisionTree {
                nodes: vec![
                    Node {
                        condition: Some(Condition::Higher { attr: 0, threshold: 1.5 }),
                        positive: 1,
                        negative: 2,
                        missing_to_positive: true,
                        value: vec![],
                        num_examples: 7.0,
                        score: 0.33,
                    },
                    Node::leaf(vec![0.25, 0.75], 3.0),
                    Node::leaf(vec![0.75, 0.25], 4.0),
                ],
            }],
            winner_take_all: false,
            oob_evaluation: Some(SelfEvaluation {
                metric: "oob accuracy".into(),
                value: 0.91,
                num_examples: 7,
            }),
        }
    }

    #[test]
    fn rf_roundtrip_preserves_predictions() {
        let m = sample_rf();
        let text = model_to_string(&m);
        let loaded = model_from_string(&text).unwrap();
        assert_eq!(loaded.model_type(), "RANDOM_FOREST");
        let obs = vec![AttrValue::Num(2.0), AttrValue::Missing];
        assert_eq!(loaded.predict_row(&obs), m.predict_row(&obs));
        let obs = vec![AttrValue::Missing, AttrValue::Missing];
        assert_eq!(loaded.predict_row(&obs), m.predict_row(&obs));
    }

    #[test]
    fn future_version_rejected() {
        let m = sample_rf();
        let text = model_to_string(&m).replace("\"format_version\": 1", "\"format_version\": 99");
        let err = match model_from_string(&text) {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert!(err.contains("newer than this library supports"), "{err}");
    }

    #[test]
    fn unknown_type_rejected_with_guidance() {
        let text = r#"{"format_version":1,"model_type":"NEURAL_NET","task":"CLASSIFICATION","label_col":0,"spec":{"columns":[]}}"#;
        let err = match model_from_string(text) {
            Err(e) => e,
            Ok(_) => panic!("expected error"),
        };
        assert!(err.contains("supports RANDOM_FOREST"), "{err}");
    }

    #[test]
    fn file_roundtrip() {
        let m = sample_rf();
        let dir = std::env::temp_dir().join("ydf_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.json");
        save_model(&m, &path).unwrap();
        let loaded = load_model(&path).unwrap();
        assert_eq!(loaded.num_classes(), 2);
        std::fs::remove_file(&path).ok();
    }
}
